//! Two-stage task scheduling — the paper's workload-balancing (WB)
//! optimization (§5.1, Algorithm 3, Figure 5), extended with a
//! cost-model-driven assignment stage for heterogeneous fleets.
//!
//! Synchronous SGD executes `p` mini-batches per iteration (one per FPGA).
//! Partitions yield different batch counts (Challenge 2), so late in the
//! epoch some partitions run dry:
//!
//! - **Stage 1** (all partitions non-empty): FPGA *i* executes the next
//!   batch of partition *i*.
//! - **Stage 2** (some partitions empty): extra batches are sampled from
//!   the remaining partitions round-robin (a persistent cursor over
//!   partition ids — Algorithm 3's `cnt`) and — with WB enabled — handed
//!   to other FPGAs. With WB disabled (the Table 7 baseline) every batch
//!   stays on its own partition's FPGA, so that FPGA executes several
//!   batches in one iteration while the others wait.
//!
//! **Assignment modes** (`--sched`): Algorithm 3 balances *batch counts*
//! ([`SchedMode::BatchCount`]: one extra per idle FPGA, in index order),
//! which is only optimal when every FPGA runs every batch at the same
//! speed. On a heterogeneous fleet (mixed generations, partially
//! populated dies, shared PCIe) [`SchedMode::Cost`] instead assigns each
//! extra to the FPGA with the least estimated finish time under a
//! per-device [`CostModel`] (seconds per batch, from the §6.2 timing
//! model driven by measured shapes and β). Extras may then stack on a
//! fast busy device or skip a slow idle one. The *partition* each extra
//! is sampled from is mode-independent, so the two modes consume
//! identical (part, seq) streams — a cost/batch-count sweep is a paired
//! comparison with a bit-identical loss sequence.
//!
//! The scheduler is pure control logic over "batches remaining per
//! partition"; the coordinator owns the actual sampling and dispatch.

/// One scheduled task: sample a batch from `part` and run it on `fpga`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    pub part: usize,
    pub fpga: usize,
}

/// Plan for one synchronous iteration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IterationPlan {
    pub tasks: Vec<Task>,
}

impl IterationPlan {
    /// Batches assigned to each FPGA (length p) — the iteration's
    /// execution time is `max` of these times the per-batch time.
    pub fn per_fpga_counts(&self, p: usize) -> Vec<usize> {
        let mut counts = vec![0usize; p];
        for t in &self.tasks {
            counts[t.fpga] += 1;
        }
        counts
    }

    /// The makespan multiplier of this iteration (max batches on one FPGA).
    pub fn makespan_batches(&self, p: usize) -> usize {
        self.per_fpga_counts(p).into_iter().max().unwrap_or(0)
    }

    /// Iteration makespan in seconds under a per-device cost model: the
    /// slowest FPGA bounds the synchronous iteration.
    pub fn makespan_seconds(&self, cost: &CostModel) -> f64 {
        self.per_fpga_counts(cost.len())
            .iter()
            .zip(&cost.batch_s)
            .map(|(&c, &s)| c as f64 * s)
            .fold(0.0f64, f64::max)
    }
}

/// Stage-2 assignment mode (`--sched`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Algorithm 3 as published: balance batch counts (one extra per idle
    /// FPGA, idle list walked in index order).
    BatchCount,
    /// Least-estimated-finish-time assignment under a per-device
    /// [`CostModel`] — reduces makespan-*seconds* on heterogeneous
    /// fleets; identical to `BatchCount` when all devices cost the same.
    Cost,
}

impl SchedMode {
    pub fn parse(s: &str) -> anyhow::Result<SchedMode> {
        match s {
            "batch-count" | "batchcount" => Ok(SchedMode::BatchCount),
            "cost" => Ok(SchedMode::Cost),
            other => anyhow::bail!("unknown scheduler mode '{other}' (batch-count|cost)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedMode::BatchCount => "batch-count",
            SchedMode::Cost => "cost",
        }
    }

    /// The other assignment mode — the auto-tuner's sched axis is a
    /// single flip between the two.
    pub fn other(&self) -> SchedMode {
        match self {
            SchedMode::BatchCount => SchedMode::Cost,
            SchedMode::Cost => SchedMode::BatchCount,
        }
    }

    pub const ALL: [SchedMode; 2] = [SchedMode::BatchCount, SchedMode::Cost];
}

/// Per-device cost model: estimated seconds per mini-batch on each FPGA.
/// Built by `perf::FleetModel::cost_model` from the fleet's per-device
/// §6.2 timing models; the scheduler itself only consumes the seconds.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub batch_s: Vec<f64>,
}

impl CostModel {
    pub fn new(batch_s: Vec<f64>) -> CostModel {
        assert!(!batch_s.is_empty(), "cost model needs at least one device");
        assert!(
            batch_s.iter().all(|s| s.is_finite() && *s > 0.0),
            "per-batch costs must be finite and positive: {batch_s:?}"
        );
        CostModel { batch_s }
    }

    /// Uniform costs — makes [`SchedMode::Cost`] coincide with
    /// [`SchedMode::BatchCount`] (useful as a homogeneous reference).
    pub fn uniform(p: usize) -> CostModel {
        CostModel::new(vec![1.0; p])
    }

    pub fn len(&self) -> usize {
        self.batch_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batch_s.is_empty()
    }
}

/// Two-stage scheduler state. The round-robin cursor (Algorithm 3's
/// `cnt`) survives across iterations so stage-2 sampling rotates through
/// partitions.
#[derive(Clone, Debug)]
pub struct TwoStageScheduler {
    p: usize,
    /// WB optimization on (two-stage) or off (baseline assignment).
    pub workload_balancing: bool,
    /// Persistent round-robin cursor over *partition ids*. Indexing a
    /// filtered still-available list (`still[cnt % still.len()]`, the
    /// pre-fix behaviour) skews toward low-index partitions whenever the
    /// list length changes between picks; a cursor over ids that skips
    /// empties keeps the rotation fair as partitions drain.
    cursor: usize,
    /// `Some` → stage-2 extras use least-estimated-finish-time
    /// assignment; `None` → Algorithm 3's batch-count balancing.
    cost: Option<CostModel>,
    /// Quarantine mask (DESIGN.md §Fault tolerance): `alive[i] == false`
    /// means FPGA *i* is lost and receives no further tasks; its
    /// partition's remaining batches drain through the stage-2 extra
    /// stream to survivors. All-alive plans are bit-identical to the
    /// pre-quarantine scheduler.
    alive: Vec<bool>,
}

impl TwoStageScheduler {
    pub fn new(p: usize, workload_balancing: bool) -> TwoStageScheduler {
        assert!(p >= 1);
        TwoStageScheduler { p, workload_balancing, cursor: 0, cost: None, alive: vec![true; p] }
    }

    /// Cost-aware scheduler ([`SchedMode::Cost`]); `cost` must have one
    /// entry per FPGA.
    pub fn with_cost(p: usize, workload_balancing: bool, cost: CostModel) -> TwoStageScheduler {
        assert!(p >= 1);
        assert_eq!(cost.len(), p, "cost model must have one entry per FPGA");
        TwoStageScheduler {
            p,
            workload_balancing,
            cursor: 0,
            cost: Some(cost),
            alive: vec![true; p],
        }
    }

    /// Remove a failed device from the fleet: it receives no task from
    /// any later `plan_iteration` call. Fails cleanly if the device id is
    /// out of range or the quarantine would leave no survivors.
    pub fn quarantine(&mut self, dev: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            dev < self.p,
            "cannot quarantine dev{dev}: the fleet has {} devices",
            self.p
        );
        self.alive[dev] = false;
        anyhow::ensure!(
            self.alive.iter().any(|&a| a),
            "all {} devices quarantined — no survivors left to run the fleet",
            self.p
        );
        Ok(())
    }

    /// The quarantine mask (one flag per FPGA).
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Devices still in the fleet.
    pub fn num_alive(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Build for a mode (uniform-cost reference when `Cost` is requested
    /// without a measured model).
    pub fn for_mode(p: usize, workload_balancing: bool, mode: SchedMode, cost: Option<CostModel>) -> TwoStageScheduler {
        match (mode, cost) {
            (SchedMode::Cost, Some(c)) => TwoStageScheduler::with_cost(p, workload_balancing, c),
            (SchedMode::Cost, None) => {
                TwoStageScheduler::with_cost(p, workload_balancing, CostModel::uniform(p))
            }
            (SchedMode::BatchCount, _) => TwoStageScheduler::new(p, workload_balancing),
        }
    }

    /// Advance the persistent cursor to the next partition with batches
    /// remaining (Algorithm 3's `cnt`, robust to drained partitions).
    fn next_available(&mut self, rem: &[usize]) -> Option<usize> {
        if rem.iter().all(|&r| r == 0) {
            return None;
        }
        loop {
            let j = self.cursor % self.p;
            self.cursor = self.cursor.wrapping_add(1);
            if rem[j] > 0 {
                return Some(j);
            }
        }
    }

    /// Plan the next iteration given `remaining[i]` = batches left in
    /// partition i. Consumes up to `p` batches (fewer at the very end of
    /// the epoch). Returns `None` when the epoch is complete.
    ///
    /// The caller must decrement `remaining` according to the returned
    /// tasks (or use [`TwoStageScheduler::plan_iteration_consuming`]).
    pub fn plan_iteration(&mut self, remaining: &[usize]) -> Option<IterationPlan> {
        assert_eq!(remaining.len(), self.p, "remaining must have one entry per partition");
        let total: usize = remaining.iter().sum();
        if total == 0 {
            return None;
        }
        let mut rem = remaining.to_vec();
        let mut tasks = Vec::with_capacity(self.p);
        let all_alive = self.alive.iter().all(|&a| a);

        if all_alive && rem.iter().all(|&r| r > 0) {
            // Stage 1: everyone samples its own partition.
            for i in 0..self.p {
                tasks.push(Task { part: i, fpga: i });
            }
            return Some(IterationPlan { tasks });
        }

        // Stage 2. Partitions with batches / idle FPGAs (Algorithm 3
        // lines 11–17). A quarantined FPGA is never idle-available; its
        // partition's batches reach survivors only through the extra
        // stream below.
        let idle: Vec<usize> =
            (0..self.p).filter(|&i| self.alive[i] && rem[i] == 0).collect();

        // Surviving non-idle FPGAs take their own partition's next batch
        // (lines 18–22 distribute to avail FPGAs).
        for i in 0..self.p {
            if self.alive[i] && rem[i] > 0 {
                tasks.push(Task { part: i, fpga: i });
                rem[i] -= 1;
            }
        }
        // Extra batches, sampled round-robin from the still-available
        // partitions (lines 23–28). The *partition* stream is
        // mode-independent; only the device each extra lands on differs.
        let mut extras = Vec::with_capacity(idle.len());
        for _ in 0..idle.len() {
            let Some(j) = self.next_available(&rem) else {
                break;
            };
            rem[j] -= 1;
            extras.push(j);
        }
        if !self.workload_balancing {
            // baseline: every batch stays on its own partition's FPGA —
            // unless that FPGA is quarantined, in which case the batch
            // falls back to WB-style assignment (idle survivors in index
            // order) so device loss never strands work.
            let mut idle_it = idle.iter();
            for &j in &extras {
                let fpga = if self.alive[j] {
                    j
                } else {
                    idle_it
                        .next()
                        .copied()
                        .unwrap_or_else(|| self.alive.iter().position(|&a| a).unwrap())
                };
                tasks.push(Task { part: j, fpga });
            }
        } else if let Some(cost) = &self.cost {
            // cost-aware WB: least-estimated-finish-time over surviving
            // FPGAs (an extra may stack on a fast busy device or leave a
            // slow idle one empty); ties break toward the lowest index,
            // which reproduces batch-count assignment on uniform costs.
            let mut load = vec![0.0f64; self.p];
            for t in &tasks {
                load[t.fpga] += cost.batch_s[t.fpga];
            }
            for &j in &extras {
                let mut best = usize::MAX;
                let mut best_finish = f64::INFINITY;
                for (f, &l) in load.iter().enumerate() {
                    if !self.alive[f] {
                        continue;
                    }
                    let finish = l + cost.batch_s[f];
                    if finish < best_finish {
                        best = f;
                        best_finish = finish;
                    }
                }
                debug_assert!(best != usize::MAX, "quarantine never leaves zero survivors");
                load[best] += cost.batch_s[best];
                tasks.push(Task { part: j, fpga: best });
            }
        } else {
            // batch-count WB: idle FPGAs take the extras in index order
            for (&j, &f) in extras.iter().zip(&idle) {
                tasks.push(Task { part: j, fpga: f });
            }
        }
        Some(IterationPlan { tasks })
    }

    /// [`TwoStageScheduler::plan_iteration`] that also consumes the
    /// planned batches from `remaining`. This is the decoupled planning
    /// stage of the host pipeline: the coordinator enumerates the whole
    /// epoch's iteration plans ahead of (and independently of) batch
    /// preparation, so prep threads can run arbitrarily far ahead.
    pub fn plan_iteration_consuming(&mut self, remaining: &mut [usize]) -> Option<IterationPlan> {
        let plan = self.plan_iteration(remaining)?;
        for t in &plan.tasks {
            debug_assert!(remaining[t.part] > 0, "scheduler over-consumed partition {}", t.part);
            remaining[t.part] -= 1;
        }
        Some(plan)
    }

    /// Plan a whole epoch; returns the iteration plans and checks the
    /// exactly-once invariant.
    pub fn plan_epoch(&mut self, batches_per_part: &[usize]) -> Vec<IterationPlan> {
        let mut rem = batches_per_part.to_vec();
        let mut plans = Vec::new();
        while let Some(plan) = self.plan_iteration(&rem) {
            for t in &plan.tasks {
                assert!(rem[t.part] > 0, "scheduler over-consumed partition {}", t.part);
                rem[t.part] -= 1;
            }
            plans.push(plan);
        }
        assert!(rem.iter().all(|&r| r == 0));
        plans
    }
}

/// Epoch makespan in batch units: Σ over iterations of the per-iteration
/// max batch count on one FPGA. This is what WB improves (Table 7).
pub fn epoch_makespan_batches(plans: &[IterationPlan], p: usize) -> usize {
    plans.iter().map(|pl| pl.makespan_batches(p)).sum()
}

/// Epoch makespan in seconds under a per-device cost model: Σ over
/// iterations of the slowest device's estimated compute time. This is
/// what [`SchedMode::Cost`] improves on heterogeneous fleets.
pub fn epoch_makespan_seconds(plans: &[IterationPlan], cost: &CostModel) -> f64 {
    plans.iter().map(|pl| pl.makespan_seconds(cost)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage1_assigns_own_partition() {
        let mut s = TwoStageScheduler::new(3, true);
        let plan = s.plan_iteration(&[5, 5, 5]).unwrap();
        assert_eq!(
            plan.tasks,
            vec![
                Task { part: 0, fpga: 0 },
                Task { part: 1, fpga: 1 },
                Task { part: 2, fpga: 2 }
            ]
        );
    }

    #[test]
    fn figure5_example() {
        // p=3, partition batch counts 4/5/3 (mini-batches 1..12 in Fig. 5).
        let mut s = TwoStageScheduler::new(3, true);
        let plans = s.plan_epoch(&[4, 5, 3]);
        // 12 batches, p=3 → with WB exactly ceil(12/3)=4 iterations of
        // makespan 1.
        assert_eq!(plans.iter().map(|p| p.tasks.len()).sum::<usize>(), 12);
        assert_eq!(epoch_makespan_batches(&plans, 3), plans.len());
        assert_eq!(plans.len(), 4);
    }

    #[test]
    fn baseline_is_slower_under_imbalance() {
        let counts = [10usize, 6, 6];
        let mut wb = TwoStageScheduler::new(3, true);
        let mut base = TwoStageScheduler::new(3, false);
        let m_wb = epoch_makespan_batches(&wb.plan_epoch(&counts), 3);
        let m_base = epoch_makespan_batches(&base.plan_epoch(&counts), 3);
        assert!(m_wb < m_base, "wb={m_wb} base={m_base}");
        // WB achieves the ideal ceil(total/p)
        assert_eq!(m_wb, (22 + 2) / 3);
    }

    #[test]
    fn exactly_once_and_iteration_width() {
        let counts = [7usize, 3, 5, 1];
        let mut s = TwoStageScheduler::new(4, true);
        let plans = s.plan_epoch(&counts);
        let mut consumed = vec![0usize; 4];
        for pl in &plans {
            assert!(pl.tasks.len() <= 4);
            for t in &pl.tasks {
                consumed[t.part] += 1;
            }
            // with WB each FPGA gets at most 1 batch per iteration
            assert!(pl.makespan_batches(4) <= 1);
        }
        assert_eq!(consumed, counts.to_vec());
    }

    #[test]
    fn round_robin_rotates_across_iterations() {
        // one partition drains immediately; extras must rotate over the
        // others rather than hammering one partition
        let mut s = TwoStageScheduler::new(3, true);
        let mut rem = vec![0usize, 9, 9];
        let mut sampled_from = vec![0usize; 3];
        for _ in 0..3 {
            let plan = s.plan_iteration(&rem).unwrap();
            for t in &plan.tasks {
                rem[t.part] -= 1;
                sampled_from[t.part] += 1;
            }
        }
        assert_eq!(sampled_from[0], 0);
        // extras alternate between partitions 1 and 2
        assert!(sampled_from[1] >= 4 && sampled_from[2] >= 4, "{sampled_from:?}");
    }

    #[test]
    fn cursor_rotation_survives_partition_drain() {
        // Regression for the pre-fix `still[cnt % still.len()]` indexing:
        // when the still-available list shrank between picks the old code
        // re-picked the same low-index partition back to back. The first
        // call's extra comes from partition 2; on the next call partition
        // 1 is back in play but the old indexing picked partition 2 again
        // — the persistent id cursor must move on to partition 3.
        let mut s = TwoStageScheduler::new(4, true);
        let extras_of = |plan: &IterationPlan, rem: &[usize]| -> Vec<usize> {
            // extras are the tasks beyond the own-partition batches
            let own: usize = rem.iter().filter(|&&r| r > 0).count();
            plan.tasks[own..].iter().map(|t| t.part).collect()
        };
        let rem1 = [0usize, 1, 2, 2];
        let plan1 = s.plan_iteration(&rem1).unwrap();
        assert_eq!(extras_of(&plan1, &rem1), vec![2], "first extra rotates to partition 2");
        let rem2 = [0usize, 2, 2, 2];
        let plan2 = s.plan_iteration(&rem2).unwrap();
        assert_eq!(
            extras_of(&plan2, &rem2),
            vec![3],
            "cursor must advance past partition 2, not re-pick it"
        );
    }

    #[test]
    fn extras_spread_evenly_across_equally_loaded_partitions() {
        // two drained partitions, three equally loaded ones → the 2
        // extras per iteration must rotate so no partition is favoured
        let mut s = TwoStageScheduler::new(5, true);
        let mut rem = vec![0usize, 0, 30, 30, 30];
        let mut extras = vec![0usize; 5];
        for _ in 0..9 {
            let plan = s.plan_iteration(&rem).unwrap();
            for (k, t) in plan.tasks.iter().enumerate() {
                rem[t.part] -= 1;
                if k >= 3 {
                    extras[t.part] += 1;
                }
            }
        }
        // 18 extras over partitions {2,3,4}: exactly 6 each
        assert_eq!(extras, vec![0, 0, 6, 6, 6], "{extras:?}");
    }

    #[test]
    fn epoch_ends_with_none() {
        let mut s = TwoStageScheduler::new(2, true);
        assert!(s.plan_iteration(&[0, 0]).is_none());
    }

    #[test]
    fn consuming_planner_matches_plan_epoch() {
        let counts = [7usize, 3, 5, 1];
        let mut a = TwoStageScheduler::new(4, true);
        let expect = a.plan_epoch(&counts);
        let mut b = TwoStageScheduler::new(4, true);
        let mut rem = counts.to_vec();
        let mut got = Vec::new();
        while let Some(p) = b.plan_iteration_consuming(&mut rem) {
            got.push(p);
        }
        assert_eq!(got, expect);
        assert!(rem.iter().all(|&r| r == 0));
    }

    #[test]
    fn single_fpga_degenerates_to_sequential() {
        let mut s = TwoStageScheduler::new(1, true);
        let plans = s.plan_epoch(&[5]);
        assert_eq!(plans.len(), 5);
        assert!(plans.iter().all(|p| p.tasks.len() == 1));
    }

    #[test]
    fn tail_iteration_can_be_narrow() {
        let mut s = TwoStageScheduler::new(4, true);
        let plans = s.plan_epoch(&[1, 1, 0, 0]);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].tasks.len(), 2);
    }

    #[test]
    fn sched_mode_parse_roundtrip() {
        for m in SchedMode::ALL {
            assert_eq!(SchedMode::parse(m.name()).unwrap(), m);
        }
        assert!(SchedMode::parse("bogus").is_err());
    }

    #[test]
    fn uniform_cost_reproduces_batch_count_plans() {
        let counts = [9usize, 3, 5, 2];
        let mut bc = TwoStageScheduler::new(4, true);
        let mut ca = TwoStageScheduler::with_cost(4, true, CostModel::uniform(4));
        assert_eq!(bc.plan_epoch(&counts), ca.plan_epoch(&counts));
    }

    #[test]
    fn cost_mode_skips_slow_idle_device_for_a_fast_one() {
        // devices 0 (slow, 2 s/batch) … 3 (fast); partitions 0,2,3 are
        // drained, one extra is available from partition 1: batch-count
        // gives it to idle FPGA 0 (the slow one, first in index order),
        // cost-aware to the fastest idle FPGA.
        let cost = CostModel::new(vec![2.0, 1.0, 1.0, 1.0]);
        let rem = [0usize, 2, 0, 0];
        let mut bc = TwoStageScheduler::new(4, true);
        let plan_bc = bc.plan_iteration(&rem).unwrap();
        assert_eq!(plan_bc.tasks[1], Task { part: 1, fpga: 0 });
        let mut ca = TwoStageScheduler::with_cost(4, true, cost.clone());
        let plan_ca = ca.plan_iteration(&rem).unwrap();
        assert_eq!(plan_ca.tasks[1], Task { part: 1, fpga: 2 });
        assert!(plan_ca.makespan_seconds(&cost) < plan_bc.makespan_seconds(&cost));
        // identical partition consumption either way (paired modes)
        let parts = |p: &IterationPlan| {
            let mut v: Vec<usize> = p.tasks.iter().map(|t| t.part).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(parts(&plan_bc), parts(&plan_ca));
    }

    #[test]
    fn cost_mode_stacks_extras_on_fast_busy_device() {
        // device 3 is >2× slower than device 0: two batches on the fast
        // busy device beat one on the slow idle one.
        let cost = CostModel::new(vec![1.0, 1.0, 1.0, 2.5]);
        let rem = [4usize, 2, 2, 0];
        let mut ca = TwoStageScheduler::with_cost(4, true, cost.clone());
        let plan = ca.plan_iteration(&rem).unwrap();
        let counts = plan.per_fpga_counts(4);
        assert_eq!(counts[3], 0, "slow idle device stays empty: {counts:?}");
        assert_eq!(counts.iter().sum::<usize>(), 4);
        assert!((plan.makespan_seconds(&cost) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quarantined_device_gets_no_tasks_and_nothing_is_lost() {
        let counts = [7usize, 3, 5, 1];
        for wb in [true, false] {
            for cost in [None, Some(CostModel::new(vec![1.0, 2.0, 1.0, 1.5]))] {
                let mut s = match &cost {
                    Some(c) => TwoStageScheduler::with_cost(4, wb, c.clone()),
                    None => TwoStageScheduler::new(4, wb),
                };
                s.quarantine(1).unwrap();
                let plans = s.plan_epoch(&counts);
                let mut consumed = vec![0usize; 4];
                for pl in &plans {
                    for t in &pl.tasks {
                        assert_ne!(t.fpga, 1, "dead device received a task (wb={wb})");
                        consumed[t.part] += 1;
                    }
                }
                assert_eq!(consumed, counts.to_vec(), "wb={wb} cost={}", cost.is_some());
            }
        }
    }

    #[test]
    fn quarantine_mid_epoch_reroutes_only_the_remainder() {
        // plan 2 healthy iterations, quarantine dev 0, drain the rest —
        // every batch still trains exactly once and the post-fault tasks
        // avoid the dead device
        let counts = [6usize, 4, 4];
        let mut s = TwoStageScheduler::new(3, true);
        let mut rem = counts.to_vec();
        let mut consumed = vec![0usize; 3];
        for _ in 0..2 {
            let pl = s.plan_iteration_consuming(&mut rem).unwrap();
            for t in &pl.tasks {
                consumed[t.part] += 1;
            }
        }
        s.quarantine(0).unwrap();
        let mut reassigned = 0;
        while let Some(pl) = s.plan_iteration_consuming(&mut rem) {
            for t in &pl.tasks {
                assert_ne!(t.fpga, 0);
                if t.part == 0 {
                    reassigned += 1;
                }
                consumed[t.part] += 1;
            }
        }
        assert_eq!(consumed, counts.to_vec());
        assert_eq!(reassigned, 4, "dev0's remaining home batches drain to survivors");
    }

    #[test]
    fn quarantining_the_last_survivor_is_an_error() {
        let mut s = TwoStageScheduler::new(2, true);
        s.quarantine(0).unwrap();
        assert_eq!(s.num_alive(), 1);
        let err = s.quarantine(1).unwrap_err().to_string();
        assert!(err.contains("no survivors"), "{err}");
        assert!(s.quarantine(7).is_err(), "out-of-range device id is rejected");
    }

    #[test]
    fn cost_mode_routes_around_a_quarantined_fast_device() {
        // the fastest device dies: extras must go to the best *survivor*
        let cost = CostModel::new(vec![1.0, 0.1, 3.0]);
        let mut s = TwoStageScheduler::with_cost(3, true, cost);
        s.quarantine(1).unwrap();
        let plans = s.plan_epoch(&[2, 2, 2]);
        for pl in &plans {
            for t in &pl.tasks {
                assert_ne!(t.fpga, 1);
            }
        }
    }

    #[test]
    fn makespan_seconds_matches_batches_under_uniform_cost() {
        let counts = [7usize, 3, 5, 1];
        let mut s = TwoStageScheduler::new(4, false);
        let plans = s.plan_epoch(&counts);
        let batches = epoch_makespan_batches(&plans, 4) as f64;
        let seconds = epoch_makespan_seconds(&plans, &CostModel::uniform(4));
        assert!((batches - seconds).abs() < 1e-12);
    }
}
