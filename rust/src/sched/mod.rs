//! Two-stage task scheduling — the paper's workload-balancing (WB)
//! optimization (§5.1, Algorithm 3, Figure 5).
//!
//! Synchronous SGD executes `p` mini-batches per iteration (one per FPGA).
//! Partitions yield different batch counts (Challenge 2), so late in the
//! epoch some partitions run dry:
//!
//! - **Stage 1** (all partitions non-empty): FPGA *i* executes the next
//!   batch of partition *i*.
//! - **Stage 2** (some partitions empty): extra batches are sampled from
//!   the remaining partitions round-robin (`cnt`) and — with WB enabled —
//!   given to *idle* FPGAs. With WB disabled (the Table 7 baseline) every
//!   batch stays on its own partition's FPGA, so that FPGA executes
//!   several batches in one iteration while the others wait.
//!
//! The scheduler is pure control logic over "batches remaining per
//! partition"; the coordinator owns the actual sampling and dispatch.

/// One scheduled task: sample a batch from `part` and run it on `fpga`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    pub part: usize,
    pub fpga: usize,
}

/// Plan for one synchronous iteration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IterationPlan {
    pub tasks: Vec<Task>,
}

impl IterationPlan {
    /// Batches assigned to each FPGA (length p) — the iteration's
    /// execution time is `max` of these times the per-batch time.
    pub fn per_fpga_counts(&self, p: usize) -> Vec<usize> {
        let mut counts = vec![0usize; p];
        for t in &self.tasks {
            counts[t.fpga] += 1;
        }
        counts
    }

    /// The makespan multiplier of this iteration (max batches on one FPGA).
    pub fn makespan_batches(&self, p: usize) -> usize {
        self.per_fpga_counts(p).into_iter().max().unwrap_or(0)
    }
}

/// Two-stage scheduler state (Algorithm 3's `cnt` survives across
/// iterations so round-robin sampling rotates through partitions).
#[derive(Clone, Debug)]
pub struct TwoStageScheduler {
    p: usize,
    /// WB optimization on (two-stage) or off (baseline assignment).
    pub workload_balancing: bool,
    cnt: usize,
}

impl TwoStageScheduler {
    pub fn new(p: usize, workload_balancing: bool) -> TwoStageScheduler {
        assert!(p >= 1);
        TwoStageScheduler { p, workload_balancing, cnt: 0 }
    }

    /// Plan the next iteration given `remaining[i]` = batches left in
    /// partition i. Consumes up to `p` batches (fewer at the very end of
    /// the epoch). Returns `None` when the epoch is complete.
    ///
    /// The caller must decrement `remaining` according to the returned
    /// tasks (or use [`TwoStageScheduler::plan_epoch`]).
    pub fn plan_iteration(&mut self, remaining: &[usize]) -> Option<IterationPlan> {
        assert_eq!(remaining.len(), self.p, "remaining must have one entry per partition");
        let total: usize = remaining.iter().sum();
        if total == 0 {
            return None;
        }
        let mut rem = remaining.to_vec();
        let mut tasks = Vec::with_capacity(self.p);

        if rem.iter().all(|&r| r > 0) {
            // Stage 1: everyone samples its own partition.
            for i in 0..self.p {
                tasks.push(Task { part: i, fpga: i });
            }
            return Some(IterationPlan { tasks });
        }

        // Stage 2. Partitions with batches / idle FPGAs (Algorithm 3
        // lines 11–17).
        let avail: Vec<usize> = (0..self.p).filter(|&i| rem[i] > 0).collect();
        let idle: Vec<usize> = (0..self.p).filter(|&i| rem[i] == 0).collect();

        // Non-idle FPGAs take their own partition's next batch (lines
        // 18–22 distribute to avail FPGAs).
        for &i in &avail {
            if rem[i] > 0 {
                tasks.push(Task { part: i, fpga: i });
                rem[i] -= 1;
            }
        }
        // Extra batches for idle FPGAs, sampled round-robin from the
        // still-available partitions (lines 23–28).
        for &f in &idle {
            // advance cnt to a partition that still has batches
            let still: Vec<usize> = avail.iter().copied().filter(|&j| rem[j] > 0).collect();
            if still.is_empty() {
                break;
            }
            let j = still[self.cnt % still.len()];
            self.cnt += 1;
            rem[j] -= 1;
            let fpga = if self.workload_balancing {
                f // WB: idle FPGA takes the extra batch
            } else {
                j // baseline: the batch stays on its own partition's FPGA
            };
            tasks.push(Task { part: j, fpga });
        }
        Some(IterationPlan { tasks })
    }

    /// [`TwoStageScheduler::plan_iteration`] that also consumes the
    /// planned batches from `remaining`. This is the decoupled planning
    /// stage of the host pipeline: the coordinator enumerates the whole
    /// epoch's iteration plans ahead of (and independently of) batch
    /// preparation, so prep threads can run arbitrarily far ahead.
    pub fn plan_iteration_consuming(&mut self, remaining: &mut [usize]) -> Option<IterationPlan> {
        let plan = self.plan_iteration(remaining)?;
        for t in &plan.tasks {
            debug_assert!(remaining[t.part] > 0, "scheduler over-consumed partition {}", t.part);
            remaining[t.part] -= 1;
        }
        Some(plan)
    }

    /// Plan a whole epoch; returns the iteration plans and checks the
    /// exactly-once invariant.
    pub fn plan_epoch(&mut self, batches_per_part: &[usize]) -> Vec<IterationPlan> {
        let mut rem = batches_per_part.to_vec();
        let mut plans = Vec::new();
        while let Some(plan) = self.plan_iteration(&rem) {
            for t in &plan.tasks {
                assert!(rem[t.part] > 0, "scheduler over-consumed partition {}", t.part);
                rem[t.part] -= 1;
            }
            plans.push(plan);
        }
        assert!(rem.iter().all(|&r| r == 0));
        plans
    }
}

/// Epoch makespan in batch units: Σ over iterations of the per-iteration
/// max batch count on one FPGA. This is what WB improves (Table 7).
pub fn epoch_makespan_batches(plans: &[IterationPlan], p: usize) -> usize {
    plans.iter().map(|pl| pl.makespan_batches(p)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage1_assigns_own_partition() {
        let mut s = TwoStageScheduler::new(3, true);
        let plan = s.plan_iteration(&[5, 5, 5]).unwrap();
        assert_eq!(
            plan.tasks,
            vec![
                Task { part: 0, fpga: 0 },
                Task { part: 1, fpga: 1 },
                Task { part: 2, fpga: 2 }
            ]
        );
    }

    #[test]
    fn figure5_example() {
        // p=3, partition batch counts 4/5/3 (mini-batches 1..12 in Fig. 5).
        let mut s = TwoStageScheduler::new(3, true);
        let plans = s.plan_epoch(&[4, 5, 3]);
        // 12 batches, p=3 → with WB exactly ceil(12/3)=4 iterations of
        // makespan 1.
        assert_eq!(plans.iter().map(|p| p.tasks.len()).sum::<usize>(), 12);
        assert_eq!(epoch_makespan_batches(&plans, 3), plans.len());
        assert_eq!(plans.len(), 4);
    }

    #[test]
    fn baseline_is_slower_under_imbalance() {
        let counts = [10usize, 6, 6];
        let mut wb = TwoStageScheduler::new(3, true);
        let mut base = TwoStageScheduler::new(3, false);
        let m_wb = epoch_makespan_batches(&wb.plan_epoch(&counts), 3);
        let m_base = epoch_makespan_batches(&base.plan_epoch(&counts), 3);
        assert!(m_wb < m_base, "wb={m_wb} base={m_base}");
        // WB achieves the ideal ceil(total/p)
        assert_eq!(m_wb, (22 + 2) / 3);
    }

    #[test]
    fn exactly_once_and_iteration_width() {
        let counts = [7usize, 3, 5, 1];
        let mut s = TwoStageScheduler::new(4, true);
        let plans = s.plan_epoch(&counts);
        let mut consumed = vec![0usize; 4];
        for pl in &plans {
            assert!(pl.tasks.len() <= 4);
            for t in &pl.tasks {
                consumed[t.part] += 1;
            }
            // with WB each FPGA gets at most 1 batch per iteration
            assert!(pl.makespan_batches(4) <= 1);
        }
        assert_eq!(consumed, counts.to_vec());
    }

    #[test]
    fn round_robin_rotates_across_iterations() {
        // one partition drains immediately; extras must rotate over the
        // others rather than hammering one partition
        let mut s = TwoStageScheduler::new(3, true);
        let mut rem = vec![0usize, 9, 9];
        let mut sampled_from = vec![0usize; 3];
        for _ in 0..3 {
            let plan = s.plan_iteration(&rem).unwrap();
            for t in &plan.tasks {
                rem[t.part] -= 1;
                sampled_from[t.part] += 1;
            }
        }
        assert_eq!(sampled_from[0], 0);
        // extras alternate between partitions 1 and 2
        assert!(sampled_from[1] >= 4 && sampled_from[2] >= 4, "{sampled_from:?}");
    }

    #[test]
    fn epoch_ends_with_none() {
        let mut s = TwoStageScheduler::new(2, true);
        assert!(s.plan_iteration(&[0, 0]).is_none());
    }

    #[test]
    fn consuming_planner_matches_plan_epoch() {
        let counts = [7usize, 3, 5, 1];
        let mut a = TwoStageScheduler::new(4, true);
        let expect = a.plan_epoch(&counts);
        let mut b = TwoStageScheduler::new(4, true);
        let mut rem = counts.to_vec();
        let mut got = Vec::new();
        while let Some(p) = b.plan_iteration_consuming(&mut rem) {
            got.push(p);
        }
        assert_eq!(got, expect);
        assert!(rem.iter().all(|&r| r == 0));
    }

    #[test]
    fn single_fpga_degenerates_to_sequential() {
        let mut s = TwoStageScheduler::new(1, true);
        let plans = s.plan_epoch(&[5]);
        assert_eq!(plans.len(), 5);
        assert!(plans.iter().all(|p| p.tasks.len() == 1));
    }

    #[test]
    fn tail_iteration_can_be_narrow() {
        let mut s = TwoStageScheduler::new(4, true);
        let plans = s.plan_epoch(&[1, 1, 0, 0]);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].tasks.len(), 2);
    }
}
