//! Dynamic caching policies: residency re-ranked at the epoch barrier
//! from accesses observed at the gradient-sync barrier.
//!
//! Both policies are capacity-bounded (`cache_ratio·|V|` rows, like
//! PaGraph's static cache) and start from the same top-out-degree fill, so
//! a policy sweep at equal `cache_ratio` is a paired comparison: epoch 0
//! is identical to the static cache, later epochs differ only by the
//! re-ranking. Tie-breaks always fall back to the initial degree rank and
//! the update is a strict-total-order top-k selection, so `end_epoch` is
//! deterministic regardless of selection internals.

use super::{CachePolicy, FeatureStore, Residency, Rows, StoreState};
use crate::graph::Dataset;
use crate::util::bitset::Bitset;

/// THE canonical cache-fill ordering: vertices in degree-descending
/// order, ties by ascending id. Shared by PaGraph's static cache
/// (`partition::pagraph::top_degree_rows` takes its first k) and the
/// dynamic policies' cold start / tie-breaks — one definition, so the
/// paired-comparison guarantee (dynamic cold start == static fill at
/// equal capacity) cannot drift.
pub fn degree_order(data: &Dataset) -> Vec<u32> {
    let g = &data.graph;
    let mut idx: Vec<u32> = (0..g.num_vertices() as u32).collect();
    idx.sort_by_key(|&v| std::cmp::Reverse((g.degree(v), std::cmp::Reverse(v))));
    idx
}

/// `rank[v]` = position of `v` in [`degree_order`], used as the
/// cold-start priority and the deterministic tie-break.
pub fn degree_rank(data: &Dataset) -> Vec<u32> {
    let order = degree_order(data);
    let mut rank = vec![0u32; order.len()];
    for (i, &v) in order.iter().enumerate() {
        rank[v as usize] = i as u32;
    }
    rank
}

/// Membership bitmap of the `k` hottest rows under `hotter_first` (a
/// strict total order ⇒ the selected set is unique/deterministic).
fn select_top_rows<F>(n: usize, k: usize, hotter_first: F) -> Bitset
where
    F: FnMut(&u32, &u32) -> std::cmp::Ordering,
{
    let mut bits = Bitset::new(n);
    let k = k.min(n);
    if k == 0 {
        return bits;
    }
    if k == n {
        for v in 0..n {
            bits.set(v);
        }
        return bits;
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.select_nth_unstable_by(k - 1, hotter_first);
    for &v in &idx[..k] {
        bits.set(v as usize);
    }
    bits
}

/// Resident vertex ids of a store's residency (checkpoint snapshot;
/// dynamic stores are always capacity-bounded subsets).
fn resident_ids(res: &Residency, n: usize) -> Vec<u32> {
    match &res.rows {
        Rows::Subset(b) => b.iter_ones().map(|v| v as u32).collect(),
        Rows::All => (0..n as u32).collect(),
    }
}

/// Rebuild a residency membership bitmap from checkpointed vertex ids,
/// rejecting out-of-range ids (corrupt or mismatched checkpoint).
fn rows_from_ids(n: usize, ids: &[u32]) -> anyhow::Result<Bitset> {
    let mut bits = Bitset::new(n);
    for &v in ids {
        anyhow::ensure!(
            (v as usize) < n,
            "checkpoint resident vertex id {v} out of range (|V| = {n})"
        );
        bits.set(v as usize);
    }
    Ok(bits)
}

/// Build a capacity-bounded store for `policy`, inheriting the dim range
/// `(dim_lo, dim_hi, feat_dim)` of the algorithm's static residency (full
/// width for DistDGL/PaGraph, the slice for P3) and cold-starting from
/// the top-degree rows.
pub fn dynamic_store(
    policy: CachePolicy,
    num_vertices: usize,
    cache_ratio: f64,
    dim: (usize, usize, usize),
    rank: Vec<u32>,
) -> Box<dyn FeatureStore> {
    assert_eq!(rank.len(), num_vertices);
    let capacity = ((num_vertices as f64) * cache_ratio).round() as usize;
    let rows =
        select_top_rows(num_vertices, capacity, |&a, &b| rank[a as usize].cmp(&rank[b as usize]));
    let residency =
        Residency { rows: Rows::Subset(rows), dim_lo: dim.0, dim_hi: dim.1, feat_dim: dim.2 };
    match policy {
        CachePolicy::Static => Box::new(residency),
        CachePolicy::Lfu => Box::new(LfuStore::new(residency, capacity, rank)),
        CachePolicy::Window => Box::new(WindowStore::new(residency, capacity, rank)),
    }
}

/// LFU/hotness cache: per-vertex access counts accumulated at the
/// gradient-sync barrier; at the epoch barrier the `capacity` rows with
/// the highest counts (tie: degree rank) become resident and all counts
/// halve, so hotness tracks recent epochs instead of the whole run.
pub struct LfuStore {
    residency: Residency,
    capacity: usize,
    counts: Vec<u64>,
    rank: Vec<u32>,
    dirty: bool,
}

impl LfuStore {
    pub fn new(residency: Residency, capacity: usize, rank: Vec<u32>) -> LfuStore {
        let n = rank.len();
        LfuStore { residency, capacity, counts: vec![0; n], rank, dirty: false }
    }

    /// Current access counts (diagnostics/tests).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
}

impl FeatureStore for LfuStore {
    fn residency(&self) -> &Residency {
        &self.residency
    }

    fn policy(&self) -> CachePolicy {
        CachePolicy::Lfu
    }

    fn observe(&mut self, v0: &[u32]) {
        for &v in v0 {
            self.counts[v as usize] += 1;
        }
        self.dirty = true;
    }

    fn end_epoch(&mut self) -> bool {
        if !self.dirty {
            return false;
        }
        self.dirty = false;
        let (counts, rank) = (&self.counts, &self.rank);
        let rows = select_top_rows(counts.len(), self.capacity, |&a, &b| {
            let (a, b) = (a as usize, b as usize);
            counts[b].cmp(&counts[a]).then(rank[a].cmp(&rank[b]))
        });
        for c in &mut self.counts {
            *c >>= 1;
        }
        let changed = self.residency.rows != Rows::Subset(rows.clone());
        if changed {
            self.residency.rows = Rows::Subset(rows);
        }
        changed
    }

    fn set_capacity(&mut self, rows: usize) -> bool {
        self.capacity = rows.min(self.counts.len());
        // re-snapshot immediately from current hotness (counts are not
        // aged — this is a capacity retarget, not an epoch update)
        let (counts, rank) = (&self.counts, &self.rank);
        let selected = select_top_rows(counts.len(), self.capacity, |&a, &b| {
            let (a, b) = (a as usize, b as usize);
            counts[b].cmp(&counts[a]).then(rank[a].cmp(&rank[b]))
        });
        if self.residency.rows != Rows::Subset(selected.clone()) {
            self.residency.rows = Rows::Subset(selected);
        }
        true
    }

    fn export_state(&self) -> StoreState {
        StoreState::Lfu {
            capacity: self.capacity as u64,
            resident: resident_ids(&self.residency, self.counts.len()),
            counts: self.counts.clone(),
        }
    }

    fn import_state(&mut self, state: &StoreState) -> anyhow::Result<()> {
        let StoreState::Lfu { capacity, resident, counts } = state else {
            anyhow::bail!(
                "checkpoint store state is {} but the live store is lfu",
                state.policy().name()
            );
        };
        let n = self.counts.len();
        anyhow::ensure!(
            counts.len() == n,
            "checkpoint lfu state covers {} vertices, store has {n}",
            counts.len()
        );
        self.capacity = (*capacity as usize).min(n);
        self.counts.copy_from_slice(counts);
        self.residency.rows = Rows::Subset(rows_from_ids(n, resident)?);
        self.dirty = false;
        Ok(())
    }
}

/// Sliding-window recency cache: a global access clock stamps every
/// observed row; at the epoch barrier the `capacity` most recently
/// accessed rows (tie: degree rank for never-seen rows) become resident —
/// the window slides with the clock, so rows that stop being sampled age
/// out even if they were hot early in training.
pub struct WindowStore {
    residency: Residency,
    capacity: usize,
    /// Clock value at each vertex's last access (0 = never accessed).
    last_seen: Vec<u64>,
    clock: u64,
    rank: Vec<u32>,
    dirty: bool,
}

impl WindowStore {
    pub fn new(residency: Residency, capacity: usize, rank: Vec<u32>) -> WindowStore {
        let n = rank.len();
        WindowStore { residency, capacity, last_seen: vec![0; n], clock: 0, rank, dirty: false }
    }
}

impl FeatureStore for WindowStore {
    fn residency(&self) -> &Residency {
        &self.residency
    }

    fn policy(&self) -> CachePolicy {
        CachePolicy::Window
    }

    fn observe(&mut self, v0: &[u32]) {
        for &v in v0 {
            self.clock += 1;
            self.last_seen[v as usize] = self.clock;
        }
        self.dirty = true;
    }

    fn end_epoch(&mut self) -> bool {
        if !self.dirty {
            return false;
        }
        self.dirty = false;
        let (seen, rank) = (&self.last_seen, &self.rank);
        let rows = select_top_rows(seen.len(), self.capacity, |&a, &b| {
            let (a, b) = (a as usize, b as usize);
            seen[b].cmp(&seen[a]).then(rank[a].cmp(&rank[b]))
        });
        let changed = self.residency.rows != Rows::Subset(rows.clone());
        if changed {
            self.residency.rows = Rows::Subset(rows);
        }
        changed
    }

    fn set_capacity(&mut self, rows: usize) -> bool {
        self.capacity = rows.min(self.last_seen.len());
        let (seen, rank) = (&self.last_seen, &self.rank);
        let selected = select_top_rows(seen.len(), self.capacity, |&a, &b| {
            let (a, b) = (a as usize, b as usize);
            seen[b].cmp(&seen[a]).then(rank[a].cmp(&rank[b]))
        });
        if self.residency.rows != Rows::Subset(selected.clone()) {
            self.residency.rows = Rows::Subset(selected);
        }
        true
    }

    fn export_state(&self) -> StoreState {
        StoreState::Window {
            capacity: self.capacity as u64,
            clock: self.clock,
            resident: resident_ids(&self.residency, self.last_seen.len()),
            last_seen: self.last_seen.clone(),
        }
    }

    fn import_state(&mut self, state: &StoreState) -> anyhow::Result<()> {
        let StoreState::Window { capacity, clock, resident, last_seen } = state else {
            anyhow::bail!(
                "checkpoint store state is {} but the live store is window",
                state.policy().name()
            );
        };
        let n = self.last_seen.len();
        anyhow::ensure!(
            last_seen.len() == n,
            "checkpoint window state covers {} vertices, store has {n}",
            last_seen.len()
        );
        self.capacity = (*capacity as usize).min(n);
        self.clock = *clock;
        self.last_seen.copy_from_slice(last_seen);
        self.residency.rows = Rows::Subset(rows_from_ids(n, resident)?);
        self.dirty = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Identity rank: vertex id = priority (lower id = hotter prior).
    fn id_rank(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    fn resident_set(s: &dyn FeatureStore) -> Vec<usize> {
        match &s.residency().rows {
            Rows::Subset(b) => b.iter_ones().collect(),
            Rows::All => panic!("expected a subset residency"),
        }
    }

    #[test]
    fn lfu_cold_start_follows_rank_then_reranks_to_observed_hot_rows() {
        let n = 100;
        let res = Residency::rows_subset(
            select_top_rows(n, 10, |&a, &b| a.cmp(&b)),
            16,
        );
        let mut s = LfuStore::new(res, 10, id_rank(n));
        assert_eq!(resident_set(&s), (0..10).collect::<Vec<_>>());

        // barrier observations: rows 50..58 are the hot set
        for _ in 0..3 {
            s.observe(&(50..58).collect::<Vec<u32>>());
        }
        assert!(s.end_epoch(), "resident set must change");
        // 8 observed rows + 2 fillers from the degree-rank prior
        assert_eq!(resident_set(&s), vec![0, 1, 50, 51, 52, 53, 54, 55, 56, 57]);
        // counts aged: 3 observations halved to 1
        assert_eq!(s.counts()[50], 1);
        assert_eq!(s.counts()[0], 0);
    }

    #[test]
    fn lfu_without_observations_is_a_no_op() {
        let n = 20;
        let res =
            Residency::rows_subset(select_top_rows(n, 5, |&a, &b| a.cmp(&b)), 8);
        let before = res.clone();
        let mut s = LfuStore::new(res, 5, id_rank(n));
        assert!(!s.end_epoch());
        assert_eq!(*s.residency(), before);
    }

    #[test]
    fn lfu_update_is_deterministic_across_instances() {
        let n = 64;
        let mk = || {
            LfuStore::new(
                Residency::rows_subset(select_top_rows(n, 8, |&a, &b| a.cmp(&b)), 4),
                8,
                id_rank(n),
            )
        };
        let (mut a, mut b) = (mk(), mk());
        for batch in [[3u32, 9, 9, 40], [40, 40, 9, 63], [1, 1, 1, 1]] {
            a.observe(&batch);
            b.observe(&batch);
        }
        a.end_epoch();
        b.end_epoch();
        assert_eq!(resident_set(&a), resident_set(&b));
    }

    #[test]
    fn window_keeps_most_recent_rows() {
        let n = 30;
        let res = Residency::rows_subset(select_top_rows(n, 3, |&a, &b| a.cmp(&b)), 8);
        let mut s = WindowStore::new(res, 3, id_rank(n));
        s.observe(&[10, 11, 12]);
        s.observe(&[20, 21, 22]);
        assert!(s.end_epoch());
        // the window slid past 10..12; only the latest 3 accesses remain
        assert_eq!(resident_set(&s), vec![20, 21, 22]);
        // next epoch: fresh accesses displace the old window
        s.observe(&[5, 6, 7]);
        assert!(s.end_epoch());
        assert_eq!(resident_set(&s), vec![5, 6, 7]);
    }

    #[test]
    fn set_capacity_resnapshots_immediately() {
        let n = 40;
        let res = Residency::rows_subset(select_top_rows(n, 4, |&a, &b| a.cmp(&b)), 8);
        let mut s = LfuStore::new(res, 4, id_rank(n));
        s.observe(&[30, 31, 30, 31]);
        // grow: observed-hot rows enter, prior rows fill the rest
        assert!(s.set_capacity(6));
        assert_eq!(resident_set(&s), vec![0, 1, 2, 3, 30, 31]);
        // shrink: hotness order wins, ties fall back to the rank prior
        assert!(s.set_capacity(2));
        assert_eq!(resident_set(&s), vec![30, 31]);
        // window store honours it too
        let resw = Residency::rows_subset(select_top_rows(n, 4, |&a, &b| a.cmp(&b)), 8);
        let mut w = WindowStore::new(resw, 4, id_rank(n));
        w.observe(&[20, 21]);
        assert!(w.set_capacity(3));
        assert_eq!(resident_set(&w), vec![0, 20, 21]);
    }

    #[test]
    fn static_store_refuses_capacity_retarget() {
        let mut r = Residency::rows_subset(select_top_rows(8, 2, |&a, &b| a.cmp(&b)), 4);
        let before = r.clone();
        assert!(!FeatureStore::set_capacity(&mut r, 5));
        assert_eq!(r, before);
    }

    #[test]
    fn capacity_edges_zero_and_full() {
        let n = 16;
        let z = dynamic_store(CachePolicy::Lfu, n, 0.0, (0, 4, 4), id_rank(n));
        assert_eq!(z.residency().resident_rows(), Some(0));
        let f = dynamic_store(CachePolicy::Window, n, 1.0, (0, 4, 4), id_rank(n));
        assert_eq!(f.residency().resident_rows(), Some(n));
    }

    #[test]
    fn dynamic_store_cold_start_matches_pagraph_fill() {
        let d = crate::graph::datasets::lookup("reddit").unwrap().build(8, 5);
        let n = d.graph.num_vertices();
        let ratio = 0.1;
        let k = ((n as f64) * ratio).round() as usize;
        let want: Vec<usize> =
            crate::partition::pagraph::top_degree_rows(&d, k).iter_ones().collect();
        for policy in [CachePolicy::Lfu, CachePolicy::Window] {
            let s = dynamic_store(policy, n, ratio, (0, 4, 4), degree_rank(&d));
            assert_eq!(resident_set(s.as_ref()), want, "{policy:?}");
        }
    }

    #[test]
    fn dim_range_is_inherited() {
        let s = dynamic_store(CachePolicy::Lfu, 8, 0.5, (2, 6, 16), id_rank(8));
        let r = s.residency();
        assert_eq!((r.dim_lo, r.dim_hi, r.feat_dim), (2, 6, 16));
        assert_eq!(r.dim_fraction(), 0.25);
    }
}
