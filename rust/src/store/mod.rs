//! Pluggable feature-store subsystem.
//!
//! The paper's feature-storing stage (Table 1) decides which vertex rows
//! are resident in each FPGA's DDR — the β of Eq. 7 and the dominant PCIe
//! traffic term. The seed hard-coded it as a static, preprocess-time
//! artifact; here it is a first-class policy:
//!
//! - [`Residency`] — the immutable resident-set snapshot the comm layer
//!   reads (rows bitmap × feature-dim range). One snapshot is taken per
//!   epoch; all prep threads read the same version, so the PR-1
//!   determinism law (bit-identical loss/Traffic across `--host-threads`
//!   × `--prefetch-depth`) is preserved by construction.
//! - [`FeatureStore`] — the policy trait: a residency query plus a
//!   deterministic `observe`/`end_epoch` update hook. `observe` is called
//!   by the coordinator at the gradient-sync barrier in (iter, tag)
//!   order; `end_epoch` applies the policy's re-ranking at the epoch
//!   barrier, versioning the next epoch's snapshot.
//! - [`CachePolicy`] — policy selector (`--cache-policy`,
//!   `HitGnn::feature_storing(policy, ratio)`): the algorithm-default
//!   static store, an LFU/hotness cache re-ranked from observed access
//!   counts (HyScale-GNN-style dynamic caching), or a sliding-window
//!   recency cache.
//! - [`TieredStore`] — the host-DRAM cache tier above on-disk feature
//!   shards (out-of-core datasets): the hierarchy becomes FPGA-DDR →
//!   host DRAM → disk, with FPGA-store misses split into DRAM hits and
//!   disk reads (`Traffic::{dram_hit,disk_read}_bytes`).

pub mod dynamic;
pub mod residency;
pub mod tiered;

pub use dynamic::{LfuStore, WindowStore};
pub use residency::{Residency, Rows};
pub use tiered::TieredStore;

/// Feature-store caching policy selector (Table 2's `Feature_Storing()`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// The algorithm's static Table-1 store: DistDGL partition-resident
    /// rows, PaGraph top-out-degree cache, P3 feature-dim slice.
    Static,
    /// LFU/hotness cache: capacity `cache_ratio·|V|` rows, re-ranked at
    /// the epoch barrier from access counts observed at the gradient-sync
    /// barrier (counts age by halving so hotness tracks recent epochs).
    Lfu,
    /// Sliding-window recency cache: the `cache_ratio·|V|` most recently
    /// accessed rows, the window advancing with the global access clock.
    Window,
}

impl CachePolicy {
    pub fn parse(s: &str) -> anyhow::Result<CachePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Ok(CachePolicy::Static),
            "lfu" | "hotness" => Ok(CachePolicy::Lfu),
            "window" | "recency" => Ok(CachePolicy::Window),
            _ => anyhow::bail!("unknown cache policy '{s}' (static|lfu|window)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CachePolicy::Static => "static",
            CachePolicy::Lfu => "lfu",
            CachePolicy::Window => "window",
        }
    }

    /// Does this policy rewrite its resident set at the epoch barrier?
    pub fn is_dynamic(&self) -> bool {
        !matches!(self, CachePolicy::Static)
    }

    pub const ALL: [CachePolicy; 3] =
        [CachePolicy::Static, CachePolicy::Lfu, CachePolicy::Window];
}

/// Serializable policy state of one feature store (checkpoint/resume —
/// DESIGN.md §Fault tolerance).
///
/// Static stores carry no state: their residency is derived
/// deterministically at preprocess time, so a resumed run rebuilds it
/// bit-identically for free. Dynamic stores snapshot their resident set
/// plus the policy accumulator (counts / recency stamps), so a resumed
/// run observes and re-ranks exactly as the straight run would.
#[derive(Clone, Debug, PartialEq)]
pub enum StoreState {
    /// No state to carry (static policies).
    Static,
    /// LFU: capacity, resident vertex ids, per-vertex access counts.
    Lfu { capacity: u64, resident: Vec<u32>, counts: Vec<u64> },
    /// Window: capacity, global clock, resident ids, last-seen stamps.
    Window { capacity: u64, clock: u64, resident: Vec<u32>, last_seen: Vec<u64> },
}

impl StoreState {
    /// The policy this state belongs to (checkpoint validation).
    pub fn policy(&self) -> CachePolicy {
        match self {
            StoreState::Static => CachePolicy::Static,
            StoreState::Lfu { .. } => CachePolicy::Lfu,
            StoreState::Window { .. } => CachePolicy::Window,
        }
    }
}

/// One FPGA's pluggable feature store: the residency snapshot the comm
/// layer reads plus the policy's deterministic update hooks.
///
/// Contract (DESIGN.md §Feature-store subsystem):
/// - `residency()` is immutable between `end_epoch` calls; callers that
///   need read access off the coordinator thread clone it (an
///   epoch-versioned snapshot) rather than sharing the store.
/// - `observe` must only be called from the coordinator at the
///   gradient-sync barrier, in (iter, tag) order — policies may be
///   order-sensitive (recency), and this ordering is what keeps dynamic
///   runs bit-identical across pipeline configurations.
/// - `end_epoch` applies the policy update at the epoch barrier and
///   returns whether the resident set changed.
pub trait FeatureStore: Send + Sync {
    /// The resident-set snapshot backing this epoch's reads.
    fn residency(&self) -> &Residency;

    /// The policy implemented by this store.
    fn policy(&self) -> CachePolicy;

    /// Record one prepared batch's layer-0 vertex accesses (deduplicated
    /// vertex ids, real rows only). Default: no-op (static stores).
    fn observe(&mut self, _v0: &[u32]) {}

    /// Apply the policy's residency update at the epoch barrier; returns
    /// true if the resident set changed. Default: no-op.
    fn end_epoch(&mut self) -> bool {
        false
    }

    /// Retarget the cache capacity to `rows` resident rows and re-snapshot
    /// immediately. Called only at the epoch barrier (the auto-tuner's
    /// cache-ratio axis), where `end_epoch` already versions the next
    /// epoch's snapshot, so the determinism law is unaffected. Returns
    /// true if the store honoured the request; static stores (the
    /// algorithm's Table-1 residency is not a tunable cache) refuse it.
    fn set_capacity(&mut self, _rows: usize) -> bool {
        false
    }

    /// Snapshot the policy state for a checkpoint. Call only at the
    /// epoch barrier (after `end_epoch`), where the resident set and the
    /// accumulators are consistent. Default: stateless (static stores).
    fn export_state(&self) -> StoreState {
        StoreState::Static
    }

    /// Restore policy state from a checkpoint taken at an epoch barrier.
    /// The state must match this store's policy and vertex count — a
    /// mismatch is a clean error, never a silent wrong resume.
    fn import_state(&mut self, state: &StoreState) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.policy() == self.policy(),
            "checkpoint store state is {} but the live store is {}",
            state.policy().name(),
            self.policy().name()
        );
        Ok(())
    }

    fn name(&self) -> &'static str {
        self.policy().name()
    }
}

/// A bare [`Residency`] is itself a valid (static) feature store.
impl FeatureStore for Residency {
    fn residency(&self) -> &Residency {
        self
    }

    fn policy(&self) -> CachePolicy {
        CachePolicy::Static
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitset::Bitset;

    #[test]
    fn policy_parse_roundtrip() {
        for p in CachePolicy::ALL {
            assert_eq!(CachePolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(CachePolicy::parse("hotness").unwrap(), CachePolicy::Lfu);
        assert_eq!(CachePolicy::parse("recency").unwrap(), CachePolicy::Window);
        assert!(CachePolicy::parse("bogus").is_err());
        assert!(!CachePolicy::Static.is_dynamic());
        assert!(CachePolicy::Lfu.is_dynamic() && CachePolicy::Window.is_dynamic());
    }

    #[test]
    fn residency_is_a_static_store() {
        let mut b = Bitset::new(8);
        b.set(2);
        let mut s = Residency::rows_subset(b, 16);
        assert_eq!(s.policy(), CachePolicy::Static);
        assert_eq!(FeatureStore::name(&s), "static");
        let before = s.residency().clone();
        s.observe(&[0, 1, 2, 3]);
        assert!(!s.end_epoch(), "static store never changes");
        assert_eq!(*s.residency(), before);
    }
}
