//! The residency snapshot: which (vertex-row, feature-dim) rectangles of
//! the global feature matrix X are resident in one FPGA's local DDR.
//!
//! A [`Residency`] is immutable for the duration of one epoch — the comm
//! layer consults it for every vertex an FPGA aggregates from (resident
//! bytes are charged to DDR bandwidth, missing bytes to the PCIe
//! host-fetch path — Eq. 7's β split), while the owning
//! [`FeatureStore`](super::FeatureStore) policy may swap the resident set
//! at the epoch barrier.

use crate::util::bitset::Bitset;

/// Which feature rows an FPGA holds locally.
#[derive(Clone, Debug, PartialEq)]
pub enum Rows {
    /// Every vertex's row is present (P3: all rows, but only a dim slice).
    All,
    /// Membership bitmap over vertex ids.
    Subset(Bitset),
}

/// One FPGA's resident-set snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct Residency {
    pub rows: Rows,
    /// Held feature dimension range `[dim_lo, dim_hi)`; full width except
    /// for P3's dimension partitioning.
    pub dim_lo: usize,
    pub dim_hi: usize,
    /// Total feature width (for fraction computations).
    pub feat_dim: usize,
}

impl Residency {
    /// Residency holding full-width rows for a vertex subset.
    pub fn rows_subset(members: Bitset, feat_dim: usize) -> Residency {
        Residency { rows: Rows::Subset(members), dim_lo: 0, dim_hi: feat_dim, feat_dim }
    }

    /// Residency holding a feature-dim slice of every row (P3).
    pub fn dim_slice(dim_lo: usize, dim_hi: usize, feat_dim: usize) -> Residency {
        assert!(dim_lo < dim_hi && dim_hi <= feat_dim);
        Residency { rows: Rows::All, dim_lo, dim_hi, feat_dim }
    }

    /// Does this residency hold vertex `v`'s row (in its dim range)?
    #[inline]
    pub fn holds_row(&self, v: u32) -> bool {
        match &self.rows {
            Rows::All => true,
            Rows::Subset(b) => b.get(v as usize),
        }
    }

    /// Fraction of the feature width held for a resident row.
    #[inline]
    pub fn dim_fraction(&self) -> f64 {
        (self.dim_hi - self.dim_lo) as f64 / self.feat_dim as f64
    }

    /// Locally available bytes for vertex `v` out of `row_bytes` total;
    /// the remainder must come from the host.
    #[inline]
    pub fn local_bytes(&self, v: u32, row_bytes: usize) -> usize {
        if self.holds_row(v) {
            (row_bytes as f64 * self.dim_fraction()).round() as usize
        } else {
            0
        }
    }

    /// Number of resident rows (None = all).
    pub fn resident_rows(&self) -> Option<usize> {
        match &self.rows {
            Rows::All => None,
            Rows::Subset(b) => Some(b.count()),
        }
    }

    /// Approximate DDR bytes this residency occupies.
    pub fn footprint_bytes(&self, num_vertices: usize, bytes_per_full_row: usize) -> usize {
        let rows = self.resident_rows().unwrap_or(num_vertices);
        (rows as f64 * bytes_per_full_row as f64 * self.dim_fraction()).round() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_residency_membership() {
        let mut b = Bitset::new(10);
        b.set(3);
        b.set(7);
        let s = Residency::rows_subset(b, 100);
        assert!(s.holds_row(3));
        assert!(!s.holds_row(4));
        assert_eq!(s.local_bytes(3, 400), 400);
        assert_eq!(s.local_bytes(4, 400), 0);
        assert_eq!(s.resident_rows(), Some(2));
    }

    #[test]
    fn dim_slice_residency_partial_bytes() {
        let s = Residency::dim_slice(0, 25, 100);
        assert!(s.holds_row(42));
        assert_eq!(s.dim_fraction(), 0.25);
        assert_eq!(s.local_bytes(42, 400), 100);
        assert_eq!(s.resident_rows(), None);
    }

    #[test]
    fn footprint_accounting() {
        let mut b = Bitset::new(1000);
        for i in 0..100 {
            b.set(i);
        }
        let s = Residency::rows_subset(b, 64);
        assert_eq!(s.footprint_bytes(1000, 256), 100 * 256);
        let p3 = Residency::dim_slice(0, 16, 64);
        assert_eq!(p3.footprint_bytes(1000, 256), 1000 * 64);
    }

    #[test]
    #[should_panic]
    fn dim_slice_validates_range() {
        Residency::dim_slice(10, 10, 64);
    }
}
