//! Host-DRAM-as-cache tiering above the on-disk feature shards.
//!
//! With an in-memory dataset, every FPGA-store miss is a DRAM copy and
//! the hierarchy ends there. Out-of-core (mmap'd pack files) adds a
//! third level — FPGA-DDR → host DRAM → disk — and [`TieredStore`]
//! makes the middle tier explicit: a capacity-bounded
//! (`dram_ratio·|V|` rows) host-side cache that reuses the exact
//! LFU/window re-ranking machinery of [`dynamic`](super::dynamic), so a
//! policy sweep compares like for like across tiers. Misses that fall
//! through DRAM are charged as disk reads.
//!
//! Determinism: the DRAM resident set is immutable within an epoch —
//! [`TieredStore::observe`] only accumulates policy state, and the
//! re-ranking happens in [`TieredStore::end_epoch`] at the epoch
//! barrier, mirroring the per-FPGA stores. Both `charge` and `observe`
//! are called by the coordinator at the gradient-sync barrier in
//! (iter, tag) order, so the byte split (and therefore every derived
//! metric) is bit-identical across `--host-threads` ×
//! `--prefetch-depth` configurations — the same determinism law the
//! per-FPGA stores obey (DESIGN.md §Out-of-core storage).

use super::dynamic::dynamic_store;
use super::{CachePolicy, FeatureStore, Residency, StoreState};
use crate::comm::Traffic;

/// The host-DRAM cache tier: one per trainer (the host's DRAM is shared
/// by all FPGAs, unlike the per-FPGA stores it sits below).
pub struct TieredStore {
    inner: Box<dyn FeatureStore>,
    num_vertices: usize,
    dram_ratio: f64,
}

impl TieredStore {
    /// A DRAM tier over `num_vertices` full-width rows with capacity
    /// `dram_ratio·num_vertices`, cold-started and tie-broken by `rank`
    /// (the canonical degree rank — same prior as the per-FPGA caches).
    pub fn new(
        policy: CachePolicy,
        num_vertices: usize,
        dram_ratio: f64,
        feat_dim: usize,
        rank: Vec<u32>,
    ) -> TieredStore {
        assert!((0.0..=1.0).contains(&dram_ratio), "dram_ratio must be in [0,1]");
        let inner =
            dynamic_store(policy, num_vertices, dram_ratio, (0, feat_dim, feat_dim), rank);
        TieredStore { inner, num_vertices, dram_ratio }
    }

    /// This epoch's DRAM resident set (immutable until `end_epoch`).
    pub fn residency(&self) -> &Residency {
        self.inner.residency()
    }

    pub fn policy(&self) -> CachePolicy {
        self.inner.policy()
    }

    pub fn dram_ratio(&self) -> f64 {
        self.dram_ratio
    }

    /// Rows currently held in the DRAM tier.
    pub fn resident_rows(&self) -> usize {
        self.inner.residency().resident_rows().unwrap_or(self.num_vertices)
    }

    /// Attribute one prepared batch's FPGA-store misses to the DRAM or
    /// disk tier. For each layer-0 vertex, whatever `fpga_res` (that
    /// FPGA's epoch residency snapshot) does not hold locally is a miss;
    /// the miss lands in `dram_hit_bytes` when the DRAM tier holds the
    /// row and in `disk_read_bytes` otherwise. This only *re-partitions*
    /// bytes that `feature_traffic` already accounted (host/f2f/dedup),
    /// so `dram_hit + disk_read == missed_bytes()` exactly — the
    /// conservation law `prop_invariants` pins.
    pub fn charge(&self, v0: &[u32], fpga_res: &Residency, row_bytes: usize, t: &mut Traffic) {
        let dram = self.inner.residency();
        let (mut hit, mut disk) = (0u64, 0u64);
        for &v in v0 {
            let miss = (row_bytes - fpga_res.local_bytes(v, row_bytes)) as u64;
            if miss == 0 {
                continue;
            }
            if dram.holds_row(v) {
                hit += miss;
            } else {
                disk += miss;
            }
        }
        t.dram_hit_bytes += hit;
        t.disk_read_bytes += disk;
    }

    /// Feed the policy's access stream (coordinator-only, (iter, tag)
    /// order at the gradient-sync barrier — same contract as the
    /// per-FPGA stores).
    pub fn observe(&mut self, v0: &[u32]) {
        self.inner.observe(v0);
    }

    /// Apply the re-ranking at the epoch barrier; true if the DRAM
    /// resident set changed.
    pub fn end_epoch(&mut self) -> bool {
        self.inner.end_epoch()
    }

    /// Snapshot the DRAM tier's policy state (checkpoint; epoch-barrier
    /// only — delegates to the inner store).
    pub fn export_state(&self) -> StoreState {
        self.inner.export_state()
    }

    /// Restore the DRAM tier's policy state from a checkpoint.
    pub fn import_state(&mut self, state: &StoreState) -> anyhow::Result<()> {
        self.inner.import_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::Rows;
    use crate::util::bitset::Bitset;

    fn id_rank(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    fn fpga_res(n: usize, held: &[u32], feat_dim: usize) -> Residency {
        let mut b = Bitset::new(n);
        for &v in held {
            b.set(v as usize);
        }
        Residency::rows_subset(b, feat_dim)
    }

    #[test]
    fn charge_partitions_misses_by_dram_membership() {
        let n = 100;
        let row = 64usize;
        // DRAM tier holds rows 0..50 (ratio 0.5, identity rank)
        let tier = TieredStore::new(CachePolicy::Static, n, 0.5, 16, id_rank(n));
        assert_eq!(tier.resident_rows(), 50);
        // FPGA holds 0 and 60; batch touches 0 (local), 10 (dram), 60
        // (local), 70 (disk)
        let res = fpga_res(n, &[0, 60], 16);
        let mut t = Traffic::default();
        tier.charge(&[0, 10, 60, 70], &res, row, &mut t);
        assert_eq!(t.dram_hit_bytes, row as u64); // vertex 10
        assert_eq!(t.disk_read_bytes, row as u64); // vertex 70
    }

    #[test]
    fn charge_conserves_missed_bytes_with_partial_dim_residency() {
        // P3-style dim slice: resident rows still miss 3/4 of the row
        let n = 16;
        let row = 400usize;
        let tier = TieredStore::new(CachePolicy::Static, n, 0.25, 100, id_rank(n));
        let p3 = Residency::dim_slice(0, 25, 100);
        let mut t = Traffic::default();
        let v0: Vec<u32> = (0..n as u32).collect();
        tier.charge(&v0, &p3, row, &mut t);
        let missed: u64 = v0.iter().map(|&v| (row - p3.local_bytes(v, row)) as u64).sum();
        assert_eq!(t.dram_hit_bytes + t.disk_read_bytes, missed);
        assert!(t.dram_hit_bytes > 0 && t.disk_read_bytes > 0);
    }

    #[test]
    fn lfu_tier_adopts_hot_rows_at_epoch_barrier_only() {
        let n = 64;
        let mut tier = TieredStore::new(CachePolicy::Lfu, n, 0.125, 8, id_rank(n));
        let cold: Vec<usize> = match &tier.residency().rows {
            Rows::Subset(b) => b.iter_ones().collect(),
            Rows::All => panic!("expected subset"),
        };
        assert_eq!(cold, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        // hot set 40..48 observed repeatedly — residency must not move
        // mid-epoch (the determinism law)...
        for _ in 0..3 {
            tier.observe(&(40..48).collect::<Vec<u32>>());
            let now: Vec<usize> = match &tier.residency().rows {
                Rows::Subset(b) => b.iter_ones().collect(),
                Rows::All => unreachable!(),
            };
            assert_eq!(now, cold, "resident set changed mid-epoch");
        }
        // ...and adopts the hot rows at the barrier, shrinking disk reads
        let res = fpga_res(n, &[], 8); // FPGA holds nothing: every row misses
        let mut before = Traffic::default();
        tier.charge(&(40..48).collect::<Vec<u32>>(), &res, 32, &mut before);
        assert_eq!(before.disk_read_bytes, 8 * 32);
        assert!(tier.end_epoch());
        let mut after = Traffic::default();
        tier.charge(&(40..48).collect::<Vec<u32>>(), &res, 32, &mut after);
        assert_eq!(after.disk_read_bytes, 0);
        assert_eq!(after.dram_hit_bytes, 8 * 32);
    }

    #[test]
    fn full_ratio_never_reads_disk() {
        let n = 32;
        let tier = TieredStore::new(CachePolicy::Window, n, 1.0, 4, id_rank(n));
        let res = fpga_res(n, &[], 4);
        let mut t = Traffic::default();
        tier.charge(&(0..n as u32).collect::<Vec<u32>>(), &res, 16, &mut t);
        assert_eq!(t.disk_read_bytes, 0);
        assert_eq!(t.dram_hit_bytes, (n * 16) as u64);
    }
}
