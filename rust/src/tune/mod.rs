//! Closed-loop epoch auto-tuning (ROADMAP item 5a).
//!
//! The DSE engine picks design parameters once, offline, from platform
//! metadata; this module corrects the *runtime-safe* subset online, between
//! epochs, from the quantities the trainer already measures at its barriers
//! (β, modeled makespan, stall split, cache hit rate). The knob set is
//! exactly the four axes the determinism tests prove loss-invariant —
//! `--host-threads`, `--prefetch-depth`, `--sched`, and (for dynamic cache
//! policies) `--cache-ratio` — so the controller can never change a loss
//! sequence, only how fast it is produced (DESIGN.md §Adaptive control).
//!
//! Control law: a guarded hill-climb with hysteresis. Each proposal changes
//! one knob, runs for one epoch, and is scored by
//! `wall_seconds + epoch_makespan_seconds` (measured host pipeline +
//! modeled fleet compute — the simulated FPGAs contribute through the
//! modeled term, real ones would move the measured term too). A grow step
//! must *improve* the score by [`ACCEPT_MARGIN`] or it is reverted and that
//! (axis, direction) is blocked for the rest of the run; a shrink step is
//! accepted if it is *no worse* than the margin (it frees host resources at
//! equal speed). Blocks are permanent, every axis has a hard cap, and
//! nothing here consumes randomness or wall-clock identity, so the
//! controller always quiesces and two runs with the same seed take the same
//! decisions whenever their measured scores order the same way.

use crate::sched::SchedMode;
use crate::util::json::Json;

/// Relative score margin a grow step must win by (and a shrink step must
/// not lose by) to be accepted.
pub const ACCEPT_MARGIN: f64 = 0.01;

/// Prep-stall fraction of epoch wall above which the host pipeline is
/// considered preparation-bound and worth widening.
pub const STALL_HIGH: f64 = 0.05;

/// Prep-stall fraction below which the pipeline is considered saturated
/// and shrink probes are worth trying.
pub const STALL_LOW: f64 = 0.01;

/// `--auto-tune` setting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AutoTuneMode {
    /// No controller at all.
    #[default]
    Off,
    /// Controller observes, proposes, and applies knob changes.
    On,
    /// Controller observes and logs but never changes a knob — the paired
    /// baseline for the determinism tests and for A/B runs.
    Freeze,
}

impl AutoTuneMode {
    pub fn parse(s: &str) -> anyhow::Result<AutoTuneMode> {
        match s {
            "off" => Ok(AutoTuneMode::Off),
            "on" => Ok(AutoTuneMode::On),
            "freeze" => Ok(AutoTuneMode::Freeze),
            other => anyhow::bail!("unknown auto-tune mode '{other}' (on|off|freeze)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AutoTuneMode::Off => "off",
            AutoTuneMode::On => "on",
            AutoTuneMode::Freeze => "freeze",
        }
    }

    pub const ALL: [AutoTuneMode; 3] = [AutoTuneMode::Off, AutoTuneMode::On, AutoTuneMode::Freeze];
}

/// The runtime-safe knob vector the controller owns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Knobs {
    pub host_threads: usize,
    pub prefetch_depth: usize,
    pub sched: SchedMode,
    pub cache_ratio: f64,
}

impl Knobs {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("host_threads", Json::num(self.host_threads as f64)),
            ("prefetch_depth", Json::num(self.prefetch_depth as f64)),
            ("sched", Json::str(self.sched.name())),
            ("cache_ratio", Json::num(self.cache_ratio)),
        ])
    }
}

/// What the controller sees after each epoch — a plain projection of
/// `EpochMetrics` so this module does not depend on the coordinator.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochObservation {
    pub wall_seconds: f64,
    /// Modeled epoch makespan under the fleet cost model (seconds).
    pub modeled_makespan_seconds: f64,
    /// Coordinator time blocked waiting on batch preparation.
    pub prep_stall_seconds: f64,
    /// Coordinator time blocked at the gradient-sync barrier.
    pub execute_stall_seconds: f64,
    pub beta: f64,
    pub cache_hit_rate: f64,
}

impl EpochObservation {
    /// The objective the hill-climb minimises: measured host wall plus
    /// modeled fleet compute.
    pub fn score(&self) -> f64 {
        self.wall_seconds + self.modeled_makespan_seconds
    }

    fn prep_stall_fraction(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.prep_stall_seconds / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Modeled prior seeded from the DSE design (`perf::FleetModel`): which
/// scheduler mode the cost model prefers for this fleet. Saves the one
/// trial epoch the sched axis would otherwise cost when the fleet is
/// homogeneous (both modes plan identically there).
#[derive(Clone, Copy, Debug)]
pub struct TunePrior {
    pub preferred_sched: SchedMode,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Axis {
    HostThreads,
    PrefetchDepth,
    Sched,
    CacheRatio,
}

impl Axis {
    fn index(self) -> usize {
        match self {
            Axis::HostThreads => 0,
            Axis::PrefetchDepth => 1,
            Axis::Sched => 2,
            Axis::CacheRatio => 3,
        }
    }

    fn from_index(i: usize) -> Option<Axis> {
        match i {
            0 => Some(Axis::HostThreads),
            1 => Some(Axis::PrefetchDepth),
            2 => Some(Axis::Sched),
            3 => Some(Axis::CacheRatio),
            _ => None,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Axis::HostThreads => "host_threads",
            Axis::PrefetchDepth => "prefetch_depth",
            Axis::Sched => "sched",
            Axis::CacheRatio => "cache_ratio",
        }
    }
}

/// One audit-log entry: what the controller concluded from this epoch's
/// observation and which knobs the *next* epoch will run with. Attached to
/// `EpochMetrics.tune` and therefore to the saved `TrainReport`.
#[derive(Clone, Debug)]
pub struct TuneDecision {
    pub epoch: usize,
    /// Resolution of the knobs that just ran: `baseline` (no trial was
    /// pending), `accept`, `revert`, or `freeze`.
    pub outcome: String,
    /// Step taken for the next epoch, e.g. `host_threads 1 -> 2`, or
    /// `hold` when the controller is quiescent.
    pub action: String,
    /// This epoch's objective (`wall_seconds + epoch_makespan_seconds`).
    pub score_s: f64,
    /// Best accepted objective so far.
    pub best_score_s: f64,
    /// Knobs in effect for the next epoch.
    pub knobs: Knobs,
}

impl TuneDecision {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::num(self.epoch as f64)),
            ("outcome", Json::str(self.outcome.clone())),
            ("action", Json::str(self.action.clone())),
            ("score_s", Json::num(self.score_s)),
            ("best_score_s", Json::num(self.best_score_s)),
            ("knobs", self.knobs.to_json()),
        ])
    }
}

struct Trial {
    axis: Axis,
    /// +1 grow, -1 shrink.
    dir: i8,
    knobs: Knobs,
    action: String,
}

/// Serializable snapshot of an in-flight trial ([`TunerState`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TrialState {
    /// [`Axis`] index (0..4).
    pub axis: u8,
    pub dir: i8,
    pub knobs: Knobs,
    pub action: String,
}

/// The controller's complete epoch-barrier state (checkpoint/resume —
/// DESIGN.md §Fault tolerance). `mode` and the cache-dynamic flag are
/// config-derived, so they are *not* part of the state: a resumed run
/// reconstructs the tuner from its config and then [`AutoTuner::restore`]s
/// this snapshot, after which the hill-climb continues exactly where the
/// straight run would be — same pending trial, same blocked steps, same
/// reference score.
#[derive(Clone, Debug, PartialEq)]
pub struct TunerState {
    pub current: Knobs,
    pub best_score: Option<f64>,
    pub trial: Option<TrialState>,
    /// `[axis][0]`=shrink blocked, `[axis][1]`=grow blocked.
    pub blocked: [[bool; 2]; 4],
    pub sched_tried: bool,
}

/// The between-epoch controller. Drive it with [`AutoTuner::observe`]
/// after every epoch and apply the returned decision's `knobs` before the
/// next one (the trainer does both in `Trainer::run`).
pub struct AutoTuner {
    mode: AutoTuneMode,
    /// Best accepted configuration.
    current: Knobs,
    best_score: Option<f64>,
    trial: Option<Trial>,
    /// Permanently blocked (axis, direction) steps: `[axis][0]`=shrink,
    /// `[axis][1]`=grow.
    blocked: [[bool; 2]; 4],
    /// The sched axis is a single flip trial; resolved at most once.
    sched_tried: bool,
    /// Whether the cache-ratio axis is live (dynamic cache policy).
    cache_dynamic: bool,
    max_host_threads: usize,
    max_prefetch_depth: usize,
    max_cache_ratio: f64,
}

impl AutoTuner {
    pub fn new(mode: AutoTuneMode, initial: Knobs, cache_dynamic: bool) -> AutoTuner {
        AutoTuner {
            mode,
            current: initial,
            best_score: None,
            trial: None,
            blocked: [[false; 2]; 4],
            sched_tried: false,
            cache_dynamic,
            max_host_threads: 8,
            max_prefetch_depth: 4,
            max_cache_ratio: 0.95,
        }
    }

    /// Seed the controller with the DSE/perf-model prior: if the modeled
    /// fleet already prefers the current scheduler mode, the flip trial is
    /// known-useless and skipped.
    pub fn with_prior(mut self, prior: TunePrior) -> AutoTuner {
        if prior.preferred_sched == self.current.sched {
            self.sched_tried = true;
        }
        self
    }

    pub fn mode(&self) -> AutoTuneMode {
        self.mode
    }

    /// Knobs currently in effect (the pending trial's, if one is running).
    pub fn knobs(&self) -> Knobs {
        self.trial.as_ref().map(|t| t.knobs).unwrap_or(self.current)
    }

    /// Snapshot the controller for a checkpoint (epoch-barrier only).
    pub fn to_state(&self) -> TunerState {
        TunerState {
            current: self.current,
            best_score: self.best_score,
            trial: self.trial.as_ref().map(|t| TrialState {
                axis: t.axis.index() as u8,
                dir: t.dir,
                knobs: t.knobs,
                action: t.action.clone(),
            }),
            blocked: self.blocked,
            sched_tried: self.sched_tried,
        }
    }

    /// Restore a checkpointed controller state onto a freshly constructed
    /// tuner (same mode / cache-dynamic flag, from the run's config).
    /// Malformed state — an axis or direction no [`Axis`] maps to — is a
    /// clean error, never a silent wrong resume.
    pub fn restore(&mut self, state: &TunerState) -> anyhow::Result<()> {
        let trial = match &state.trial {
            None => None,
            Some(t) => {
                let axis = Axis::from_index(t.axis as usize).ok_or_else(|| {
                    anyhow::anyhow!("checkpoint tuner trial axis {} is not a knob axis", t.axis)
                })?;
                anyhow::ensure!(
                    t.dir == 1 || t.dir == -1,
                    "checkpoint tuner trial direction {} is not +1/-1",
                    t.dir
                );
                Some(Trial { axis, dir: t.dir, knobs: t.knobs, action: t.action.clone() })
            }
        };
        self.current = state.current;
        self.best_score = state.best_score;
        self.trial = trial;
        self.blocked = state.blocked;
        self.sched_tried = state.sched_tried;
        Ok(())
    }

    fn blocked_step(&self, axis: Axis, dir: i8) -> bool {
        self.blocked[axis.index()][if dir > 0 { 1 } else { 0 }]
    }

    fn block(&mut self, axis: Axis, dir: i8) {
        self.blocked[axis.index()][if dir > 0 { 1 } else { 0 }] = true;
    }

    /// Consume one epoch's observation (measured under [`Self::knobs`])
    /// and decide the next epoch's configuration.
    pub fn observe(&mut self, epoch: usize, obs: &EpochObservation) -> TuneDecision {
        let score = obs.score();
        let outcome = match self.trial.take() {
            None => {
                // fresh measurement of the accepted configuration
                self.best_score = Some(score);
                if self.mode == AutoTuneMode::Freeze { "freeze" } else { "baseline" }
            }
            Some(t) => {
                let best = self.best_score.expect("trial implies a baseline score");
                let win = score <= best * (1.0 - ACCEPT_MARGIN);
                let hold = score <= best * (1.0 + ACCEPT_MARGIN);
                if (t.dir > 0 && win) || (t.dir < 0 && hold) {
                    self.current = t.knobs;
                    self.best_score = Some(score.min(best));
                    "accept"
                } else {
                    self.block(t.axis, t.dir);
                    "revert"
                }
            }
        };

        // After a revert the next epoch re-measures the restored baseline
        // (outcome `baseline`) before any new trial, so a fresh trial is
        // never scored against a stale reference.
        let action = if self.mode == AutoTuneMode::On && outcome != "revert" {
            match self.propose(obs) {
                Some(t) => {
                    let a = t.action.clone();
                    self.trial = Some(t);
                    a
                }
                None => "hold".to_string(),
            }
        } else {
            "hold".to_string()
        };

        TuneDecision {
            epoch,
            outcome: outcome.to_string(),
            action,
            score_s: score,
            best_score_s: self.best_score.unwrap_or(score),
            knobs: self.knobs(),
        }
    }

    /// Signal-directed single-knob proposal, or `None` when quiescent.
    fn propose(&mut self, obs: &EpochObservation) -> Option<Trial> {
        let k = self.current;
        let stall = obs.prep_stall_fraction();

        // 1. Scheduler flip: one trial, taken early — the modeled makespan
        //    term responds deterministically, so one epoch settles it.
        if !self.sched_tried && !self.blocked_step(Axis::Sched, 1) {
            self.sched_tried = true;
            let flipped = k.sched.other();
            return Some(Trial {
                axis: Axis::Sched,
                dir: 1,
                knobs: Knobs { sched: flipped, ..k },
                action: format!("sched {} -> {}", k.sched.name(), flipped.name()),
            });
        }

        // 2. Preparation-bound: widen the prep pool first (doubling), then
        //    deepen the prefetch window.
        if stall > STALL_HIGH {
            if k.host_threads < self.max_host_threads && !self.blocked_step(Axis::HostThreads, 1) {
                let next = (k.host_threads * 2).min(self.max_host_threads);
                return Some(Trial {
                    axis: Axis::HostThreads,
                    dir: 1,
                    knobs: Knobs { host_threads: next, ..k },
                    action: format!("host_threads {} -> {}", k.host_threads, next),
                });
            }
            if k.prefetch_depth < self.max_prefetch_depth
                && !self.blocked_step(Axis::PrefetchDepth, 1)
            {
                let next = k.prefetch_depth + 1;
                return Some(Trial {
                    axis: Axis::PrefetchDepth,
                    dir: 1,
                    knobs: Knobs { prefetch_depth: next, ..k },
                    action: format!("prefetch_depth {} -> {}", k.prefetch_depth, next),
                });
            }
        }

        // 3. Dynamic cache policies only: grow the resident set while rows
        //    still miss (re-snapshot happens at the epoch barrier).
        if self.cache_dynamic
            && obs.cache_hit_rate < 0.95
            && k.cache_ratio + 0.05 <= self.max_cache_ratio + 1e-9
            && !self.blocked_step(Axis::CacheRatio, 1)
        {
            let next = k.cache_ratio + 0.05;
            return Some(Trial {
                axis: Axis::CacheRatio,
                dir: 1,
                knobs: Knobs { cache_ratio: next, ..k },
                action: format!("cache_ratio {:.2} -> {:.2}", k.cache_ratio, next),
            });
        }

        // 4. Saturated pipeline: probe shrinking (accepted only if no
        //    worse than the margin — frees host resources at equal speed).
        if stall < STALL_LOW {
            if k.prefetch_depth > 1 && !self.blocked_step(Axis::PrefetchDepth, -1) {
                let next = k.prefetch_depth - 1;
                return Some(Trial {
                    axis: Axis::PrefetchDepth,
                    dir: -1,
                    knobs: Knobs { prefetch_depth: next, ..k },
                    action: format!("prefetch_depth {} -> {}", k.prefetch_depth, next),
                });
            }
            if k.host_threads > 1 && !self.blocked_step(Axis::HostThreads, -1) {
                let next = k.host_threads / 2;
                return Some(Trial {
                    axis: Axis::HostThreads,
                    dir: -1,
                    knobs: Knobs { host_threads: next, ..k },
                    action: format!("host_threads {} -> {}", k.host_threads, next),
                });
            }
        }

        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs() -> Knobs {
        Knobs {
            host_threads: 1,
            prefetch_depth: 1,
            sched: SchedMode::BatchCount,
            cache_ratio: 0.2,
        }
    }

    fn obs(wall: f64, makespan: f64, prep_stall: f64) -> EpochObservation {
        EpochObservation {
            wall_seconds: wall,
            modeled_makespan_seconds: makespan,
            prep_stall_seconds: prep_stall,
            execute_stall_seconds: 0.0,
            beta: 0.8,
            cache_hit_rate: 0.5,
        }
    }

    #[test]
    fn mode_parses_and_round_trips() {
        for m in AutoTuneMode::ALL {
            assert_eq!(AutoTuneMode::parse(m.name()).unwrap(), m);
        }
        assert!(AutoTuneMode::parse("bogus").is_err());
    }

    #[test]
    fn freeze_never_changes_knobs() {
        let mut t = AutoTuner::new(AutoTuneMode::Freeze, knobs(), false);
        for e in 0..5 {
            let d = t.observe(e, &obs(1.0 - 0.1 * e as f64, 0.5, 0.9));
            assert_eq!(d.outcome, "freeze");
            assert_eq!(d.action, "hold");
            assert_eq!(d.knobs, knobs());
        }
    }

    #[test]
    fn sched_flip_is_trialed_first_and_accepted_on_improvement() {
        let mut t = AutoTuner::new(AutoTuneMode::On, knobs(), false);
        let d0 = t.observe(0, &obs(1.0, 1.0, 0.0));
        assert_eq!(d0.outcome, "baseline");
        assert_eq!(d0.action, "sched batch-count -> cost");
        assert_eq!(d0.knobs.sched, SchedMode::Cost);
        // the flip shrinks the modeled makespan → accept
        let d1 = t.observe(1, &obs(1.0, 0.7, 0.0));
        assert_eq!(d1.outcome, "accept");
        assert_eq!(t.current.sched, SchedMode::Cost);
    }

    #[test]
    fn regressing_step_is_reverted_and_blocked() {
        let mut t = AutoTuner::new(AutoTuneMode::On, knobs(), false)
            .with_prior(TunePrior { preferred_sched: SchedMode::BatchCount });
        // prep-bound baseline → proposes host_threads 1 -> 2
        let d0 = t.observe(0, &obs(1.0, 0.1, 0.5));
        assert_eq!(d0.action, "host_threads 1 -> 2");
        // trial regresses → revert, axis+direction blocked, no new trial
        // until the restored baseline has been re-measured
        let d1 = t.observe(1, &obs(1.3, 0.1, 0.5));
        assert_eq!(d1.outcome, "revert");
        assert_eq!(d1.action, "hold");
        assert_eq!(d1.knobs.host_threads, 1);
        // still prep-bound, but host-threads growth is blocked → prefetch
        let d2 = t.observe(2, &obs(1.0, 0.1, 0.5));
        assert_eq!(d2.outcome, "baseline");
        assert_eq!(d2.action, "prefetch_depth 1 -> 2");
    }

    #[test]
    fn prior_skips_the_useless_sched_flip() {
        let mut t = AutoTuner::new(AutoTuneMode::On, knobs(), false)
            .with_prior(TunePrior { preferred_sched: SchedMode::BatchCount });
        let d0 = t.observe(0, &obs(1.0, 0.1, 0.5));
        assert!(d0.action.starts_with("host_threads"), "{}", d0.action);
    }

    #[test]
    fn climbs_to_cap_then_quiesces() {
        let mut t = AutoTuner::new(AutoTuneMode::On, knobs(), false)
            .with_prior(TunePrior { preferred_sched: SchedMode::BatchCount });
        // every grow step wins big and stays prep-bound: 1→2→4→8, capped
        let mut wall = 2.0;
        let mut d = t.observe(0, &obs(wall, 0.1, wall * 0.8));
        for e in 1..4 {
            wall *= 0.6;
            d = t.observe(e, &obs(wall, 0.1, wall * 0.8));
            assert_eq!(d.outcome, "accept");
        }
        assert_eq!(t.current.host_threads, 8);
        // still prep-bound but the axis is capped → prefetch grows next
        assert_eq!(d.action, "prefetch_depth 1 -> 2");
    }

    #[test]
    fn shrink_probe_accepts_on_equal_score() {
        let start = Knobs { host_threads: 4, prefetch_depth: 2, ..knobs() };
        let mut t = AutoTuner::new(AutoTuneMode::On, start, false)
            .with_prior(TunePrior { preferred_sched: SchedMode::BatchCount });
        // saturated pipeline (no prep stall) → shrink prefetch first
        let d0 = t.observe(0, &obs(1.0, 0.1, 0.0));
        assert_eq!(d0.action, "prefetch_depth 2 -> 1");
        // equal score → accepted (frees resources at no cost)
        let d1 = t.observe(1, &obs(1.0, 0.1, 0.0));
        assert_eq!(d1.outcome, "accept");
        assert_eq!(t.current.prefetch_depth, 1);
    }

    #[test]
    fn cache_axis_only_moves_for_dynamic_policies() {
        let sat = |t: &mut AutoTuner, e| t.observe(e, &obs(1.0, 0.1, 0.02));
        let mut s = AutoTuner::new(AutoTuneMode::On, knobs(), false)
            .with_prior(TunePrior { preferred_sched: SchedMode::BatchCount });
        let d = sat(&mut s, 0);
        assert_eq!(d.action, "hold", "static cache policy must not move cache_ratio");
        let mut dynp = AutoTuner::new(AutoTuneMode::On, knobs(), true)
            .with_prior(TunePrior { preferred_sched: SchedMode::BatchCount });
        let d = sat(&mut dynp, 0);
        assert_eq!(d.action, "cache_ratio 0.20 -> 0.25");
    }

    #[test]
    fn state_roundtrip_resumes_the_climb() {
        // drive a tuner mid-climb (pending trial, one blocked step),
        // snapshot it, restore onto a fresh instance, then feed both the
        // same observation stream — decisions must be identical
        let mut a = AutoTuner::new(AutoTuneMode::On, knobs(), true);
        a.observe(0, &obs(1.0, 1.0, 0.5)); // baseline → sched trial
        a.observe(1, &obs(1.3, 1.0, 0.5)); // revert + block
        a.observe(2, &obs(1.0, 1.0, 0.5)); // re-baseline → host_threads trial
        let snap = a.to_state();
        assert!(snap.trial.is_some());
        assert!(snap.sched_tried);
        let mut b = AutoTuner::new(AutoTuneMode::On, knobs(), true);
        b.restore(&snap).unwrap();
        assert_eq!(b.knobs(), a.knobs());
        for (e, o) in
            [(3, obs(0.8, 1.0, 0.5)), (4, obs(0.7, 1.0, 0.3)), (5, obs(0.9, 1.0, 0.0))]
        {
            let da = a.observe(e, &o);
            let db = b.observe(e, &o);
            assert_eq!((da.outcome, da.action, da.knobs), (db.outcome, db.action, db.knobs));
        }
    }

    #[test]
    fn restore_rejects_malformed_trials() {
        let mut t = AutoTuner::new(AutoTuneMode::On, knobs(), false);
        let bad_axis = TunerState {
            current: knobs(),
            best_score: Some(1.0),
            trial: Some(TrialState { axis: 9, dir: 1, knobs: knobs(), action: "x".into() }),
            blocked: [[false; 2]; 4],
            sched_tried: false,
        };
        assert!(t.restore(&bad_axis).unwrap_err().to_string().contains("axis 9"));
        let bad_dir = TunerState {
            trial: Some(TrialState { axis: 0, dir: 0, knobs: knobs(), action: "x".into() }),
            ..bad_axis
        };
        assert!(t.restore(&bad_dir).is_err());
    }

    #[test]
    fn decision_serialises() {
        let mut t = AutoTuner::new(AutoTuneMode::On, knobs(), false);
        let d = t.observe(0, &obs(1.0, 0.5, 0.0));
        let j = d.to_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.req_str("outcome").unwrap(), "baseline");
        assert!(parsed.get("knobs").unwrap().get("sched").is_some());
        assert!((parsed.req_f64("score_s").unwrap() - 1.5).abs() < 1e-12);
    }
}
