//! Counting global allocator (feature `alloc-count`) — the measurement
//! harness behind the zero-allocation hot-path guarantee (DESIGN.md
//! §Hot-path memory & kernels).
//!
//! With `--features alloc-count` the crate installs a [`GlobalAlloc`]
//! wrapper around the system allocator that counts every `alloc` /
//! `alloc_zeroed` / `realloc` process-wide. `tests/alloc_steady_state.rs`
//! (the only test in its binary, so no concurrent test threads pollute
//! the counter) drives the sampler + feature-gather steady state through
//! it and asserts **zero** allocations per iteration after warm-up; the
//! `micro_host` kernel sweep reports the same number. The feature is
//! measurement-only: it changes no behavior and is off by default.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// System-allocator wrapper counting allocation events (not bytes —
/// the hot-path contract is "no allocator traffic at all").
pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Total allocation events (alloc + alloc_zeroed + realloc) since process
/// start. Subtract two readings to audit a region of code.
pub fn allocation_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_observes_heap_traffic() {
        let before = allocation_count();
        let v: Vec<u64> = (0..128).collect();
        std::hint::black_box(&v);
        assert!(allocation_count() > before, "Vec allocation must be counted");
    }
}
