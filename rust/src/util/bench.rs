//! Miniature measurement harness (criterion is unavailable offline).
//!
//! Benches are plain binaries with `harness = false`; each calls
//! [`Bench::new`] and registers closures via [`Bench::measure`], or prints
//! analytic tables directly. Timing methodology: warmup runs, then `iters`
//! timed runs; report median + IQR, following criterion's spirit.
//!
//! Two cross-cutting services live here so every bench behaves uniformly:
//!
//! - **Quick mode** — [`quick`] / [`env_knob`] give all benches one
//!   interpretation of `HITGNN_BENCH_QUICK`: when it is set, iteration
//!   counts, graph scale shifts, and batch counts fall back to small
//!   smoke-run defaults unless explicitly overridden. CI uses this to run
//!   the full bench matrix in seconds.
//! - **Machine-readable output** — a [`BenchSuite`] collects every
//!   [`Bench`]'s measurement table (plus derived throughput lines) and
//!   writes it as `BENCH_<area>.json` (schema `hitgnn-bench-v1`, see
//!   `bench/compare.py`), so perf trajectories diff across commits
//!   without scraping stdout.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::json::Json;
use super::stats;

/// True when `HITGNN_BENCH_QUICK` is set (any value): benches shrink
/// their workloads to smoke-run scale.
pub fn quick() -> bool {
    std::env::var_os("HITGNN_BENCH_QUICK").is_some()
}

/// Resolve a numeric bench knob from the environment with distinct
/// full-run and quick-run defaults. Unparseable values warn and fall back
/// to the applicable default instead of being silently swallowed.
pub fn env_knob(var: &str, full_default: usize, quick_default: usize) -> usize {
    let default = if quick() { quick_default } else { full_default };
    parse_knob(var, std::env::var(var).ok().as_deref(), default)
}

fn parse_knob(var: &str, raw: Option<&str>, default: usize) -> usize {
    match raw {
        None => default,
        Some(s) => match s.parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("warning: ignoring unparseable {var}={s:?}; using {default}");
                default
            }
        },
    }
}

/// The current git revision for bench provenance: `git rev-parse --short
/// HEAD`, falling back to `HITGNN_GIT_REV`, then `"unknown"` (benches
/// must run outside a checkout too, e.g. from an unpacked artifact).
pub fn git_rev() -> String {
    let git = std::process::Command::new("git").args(["rev-parse", "--short", "HEAD"]).output();
    if let Ok(out) = git {
        if out.status.success() {
            let rev = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !rev.is_empty() {
                return rev;
            }
        }
    }
    std::env::var("HITGNN_GIT_REV").unwrap_or_else(|_| "unknown".to_string())
}

/// Directory BENCH_*.json files are written to (`HITGNN_BENCH_OUT`,
/// default the working directory).
pub fn out_dir() -> PathBuf {
    PathBuf::from(std::env::var("HITGNN_BENCH_OUT").unwrap_or_else(|_| ".".to_string()))
}

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median_s: f64,
    pub p25_s: f64,
    pub p75_s: f64,
    pub iters: usize,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("median_s", Json::num(self.median_s)),
            ("p25_s", Json::num(self.p25_s)),
            ("p75_s", Json::num(self.p75_s)),
            ("iters", Json::num(self.iters as f64)),
        ])
    }
}

/// A derived rate (e.g. NVTPS) computed from a measurement.
#[derive(Clone, Debug)]
pub struct Derived {
    pub name: String,
    pub per_s: f64,
    pub unit: String,
}

/// Bench context: collects measurements and prints a uniform report.
pub struct Bench {
    title: String,
    warmup: usize,
    iters: usize,
    results: Vec<Measurement>,
    derived: Vec<Derived>,
}

impl Bench {
    pub fn new(title: &str) -> Bench {
        // Allow quick runs via env (used by `make test` smoke paths and
        // the CI trajectory job). `iters` is clamped to >= 1 — a zero
        // sample count would make median/IQR undefined.
        let iters = env_knob("HITGNN_BENCH_ITERS", 10, 3).max(1);
        let warmup = env_knob("HITGNN_BENCH_WARMUP", 3, 1);
        println!("\n=== bench: {title} (warmup={warmup}, iters={iters}) ===");
        Bench { title: title.to_string(), warmup, iters, results: Vec::new(), derived: Vec::new() }
    }

    /// The configured timed-repetition count (for callers that collect
    /// their own samples and report them via [`Bench::record`]).
    pub fn iters(&self) -> usize {
        self.iters
    }

    pub fn warmup(&self) -> usize {
        self.warmup
    }

    /// Time `f`, which receives the iteration index and must return some
    /// value to keep the optimizer honest (the value is black-boxed).
    pub fn measure<T>(&mut self, name: &str, mut f: impl FnMut(usize) -> T) -> &Measurement {
        for i in 0..self.warmup {
            black_box(f(i));
        }
        let mut samples = Vec::with_capacity(self.iters);
        for i in 0..self.iters {
            let t0 = Instant::now();
            black_box(f(i));
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.record(name, &samples)
    }

    /// Record a measurement from externally-collected samples (seconds) —
    /// for benches whose timed quantity is reported by the workload
    /// itself (e.g. an epoch wall clock measured inside the trainer,
    /// excluding setup).
    pub fn record(&mut self, name: &str, samples: &[f64]) -> &Measurement {
        assert!(!samples.is_empty(), "record needs at least one sample");
        let m = Measurement {
            name: name.to_string(),
            median_s: stats::median(samples),
            p25_s: stats::percentile(samples, 0.25),
            p75_s: stats::percentile(samples, 0.75),
            iters: samples.len(),
        };
        println!(
            "  {:<44} {:>12} [{} .. {}]",
            m.name,
            stats::fmt_secs(m.median_s),
            stats::fmt_secs(m.p25_s),
            stats::fmt_secs(m.p75_s),
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Emit (and record) a throughput line derived from a prior
    /// measurement.
    pub fn throughput(&mut self, name: &str, units: f64, median_s: f64, unit_name: &str) {
        let per_s = units / median_s;
        println!("  {:<44} {:>12} {unit_name}/s", name, stats::si(per_s));
        self.derived.push(Derived {
            name: name.to_string(),
            per_s,
            unit: unit_name.to_string(),
        });
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// This bench's entry in the `hitgnn-bench-v1` report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("warmup", Json::num(self.warmup as f64)),
            ("iters", Json::num(self.iters as f64)),
            (
                "measurements",
                Json::arr(self.results.iter().map(Measurement::to_json).collect()),
            ),
            (
                "derived",
                Json::arr(
                    self.derived
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("name", Json::str(&d.name)),
                                ("per_s", Json::num(d.per_s)),
                                ("unit", Json::str(&d.unit)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn finish(self) {
        println!("=== end bench: {} ===", self.title);
    }
}

/// Collector for one BENCH_<area>.json perf-trajectory file.
///
/// Schema (`hitgnn-bench-v1`): `{schema, area, git_rev, quick,
/// benches: [Bench::to_json()...]}` plus any extra top-level sections
/// added via [`BenchSuite::extra`] (e.g. the auto-tune trajectory).
/// `bench/compare.py` diffs the `benches` measurements between two such
/// files.
pub struct BenchSuite {
    area: String,
    benches: Vec<Json>,
    extras: Vec<(String, Json)>,
}

impl BenchSuite {
    pub fn new(area: &str) -> BenchSuite {
        BenchSuite { area: area.to_string(), benches: Vec::new(), extras: Vec::new() }
    }

    /// Record a finished bench's measurement table. Call after the last
    /// `measure`/`throughput` on it (before `finish`, which consumes it).
    pub fn add(&mut self, bench: &Bench) {
        self.benches.push(bench.to_json());
    }

    /// Attach an extra top-level section (ignored by the generic
    /// measurement differ, but part of the trajectory record).
    pub fn extra(&mut self, key: &str, value: Json) {
        self.extras.push((key.to_string(), value));
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schema", Json::str("hitgnn-bench-v1")),
            ("area", Json::str(&self.area)),
            ("git_rev", Json::str(git_rev())),
            ("quick", Json::Bool(quick())),
            ("benches", Json::arr(self.benches.clone())),
        ];
        for (k, v) in &self.extras {
            fields.push((k.as_str(), v.clone()));
        }
        Json::obj(fields)
    }

    /// Write `BENCH_<area>.json` under `dir` and return the path.
    pub fn write(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        let path = dir.join(format!("BENCH_{}.json", self.area));
        std::fs::write(&path, self.to_json().pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
        Ok(path)
    }
}

/// `std::hint::black_box` wrapper (stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Markdown-style table printer used by the table/figure benches so the
/// output can be pasted into EXPERIMENTS.md directly.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", fmt_row(&sep));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_ordered_quartiles() {
        std::env::set_var("HITGNN_BENCH_ITERS", "5");
        std::env::set_var("HITGNN_BENCH_WARMUP", "1");
        let mut b = Bench::new("unit");
        let m = b.measure("spin", |_| {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(m.p25_s <= m.median_s && m.median_s <= m.p75_s);
        assert!(m.median_s > 0.0);
        std::env::remove_var("HITGNN_BENCH_ITERS");
        std::env::remove_var("HITGNN_BENCH_WARMUP");
    }

    #[test]
    fn knob_parser_handles_garbage_and_absence() {
        assert_eq!(parse_knob("X", None, 10), 10);
        assert_eq!(parse_knob("X", Some("7"), 10), 7);
        assert_eq!(parse_knob("X", Some("0"), 10), 0); // clamp is the caller's
        assert_eq!(parse_knob("X", Some("seven"), 10), 10);
        assert_eq!(parse_knob("X", Some(""), 10), 10);
        assert_eq!(parse_knob("X", Some("-3"), 10), 10);
        // Bench::new clamps iters to >= 1 so the median is always over a
        // non-empty sample set (regression test for ITERS=0 panicking in
        // stats::percentile).
        assert_eq!(parse_knob("HITGNN_BENCH_ITERS", Some("0"), 10).max(1), 1);
    }

    #[test]
    fn bench_report_serialises_measurements_and_derived() {
        let mut b = Bench {
            title: "t".into(),
            warmup: 0,
            iters: 2,
            results: Vec::new(),
            derived: Vec::new(),
        };
        b.measure("noop", |i| i);
        b.throughput("rate", 100.0, 0.5, "V");
        let j = b.to_json();
        assert_eq!(j.req_str("title").unwrap(), "t");
        let ms = j.req("measurements").unwrap().as_arr().unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].req_str("name").unwrap(), "noop");
        assert_eq!(ms[0].req_usize("iters").unwrap(), 2);
        let ds = j.req("derived").unwrap().as_arr().unwrap();
        assert_eq!(ds.len(), 1);
        assert!((ds[0].req_f64("per_s").unwrap() - 200.0).abs() < 1e-9);
        assert_eq!(ds[0].req_str("unit").unwrap(), "V");
    }

    #[test]
    fn suite_writes_schema_v1_file() {
        let mut suite = BenchSuite::new("unit_suite");
        let mut b = Bench {
            title: "t".into(),
            warmup: 0,
            iters: 1,
            results: Vec::new(),
            derived: Vec::new(),
        };
        b.measure("noop", |i| i);
        suite.add(&b);
        suite.extra("note", Json::str("hello"));
        let dir = std::env::temp_dir().join(format!("hitgnn_bench_suite_{}", std::process::id()));
        let path = suite.write(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_unit_suite.json");
        let back = Json::from_file(&path).unwrap();
        assert_eq!(back.req_str("schema").unwrap(), "hitgnn-bench-v1");
        assert_eq!(back.req_str("area").unwrap(), "unit_suite");
        assert!(!back.req_str("git_rev").unwrap().is_empty());
        assert_eq!(back.req("benches").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(back.req_str("note").unwrap(), "hello");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["dataset", "NVTPS"]);
        t.row(&["reddit".into(), "32.5 M".into()]);
        t.print();
    }
}
