//! Miniature measurement harness (criterion is unavailable offline).
//!
//! Benches are plain binaries with `harness = false`; each calls
//! [`Bench::new`] and registers closures via [`Bench::measure`], or prints
//! analytic tables directly. Timing methodology: warmup runs, then `iters`
//! timed runs; report median + IQR, following criterion's spirit.

use std::time::Instant;

use super::stats;

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median_s: f64,
    pub p25_s: f64,
    pub p75_s: f64,
    pub iters: usize,
}

/// Bench context: collects measurements and prints a uniform report.
pub struct Bench {
    title: String,
    warmup: usize,
    iters: usize,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Bench {
        // Allow quick runs via env (used by `make test` smoke paths).
        let iters = std::env::var("HITGNN_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10);
        let warmup = std::env::var("HITGNN_BENCH_WARMUP")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        println!("\n=== bench: {title} (warmup={warmup}, iters={iters}) ===");
        Bench { title: title.to_string(), warmup, iters, results: Vec::new() }
    }

    /// Time `f`, which receives the iteration index and must return some
    /// value to keep the optimizer honest (the value is black-boxed).
    pub fn measure<T>(&mut self, name: &str, mut f: impl FnMut(usize) -> T) -> &Measurement {
        for i in 0..self.warmup {
            black_box(f(i));
        }
        let mut samples = Vec::with_capacity(self.iters);
        for i in 0..self.iters {
            let t0 = Instant::now();
            black_box(f(i));
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            median_s: stats::median(&samples),
            p25_s: stats::percentile(&samples, 0.25),
            p75_s: stats::percentile(&samples, 0.75),
            iters: self.iters,
        };
        println!(
            "  {:<44} {:>12} [{} .. {}]",
            m.name,
            stats::fmt_secs(m.median_s),
            stats::fmt_secs(m.p25_s),
            stats::fmt_secs(m.p75_s),
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Emit a throughput line derived from a prior measurement.
    pub fn throughput(&self, name: &str, units: f64, median_s: f64, unit_name: &str) {
        println!(
            "  {:<44} {:>12} {unit_name}/s",
            name,
            stats::si(units / median_s)
        );
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn finish(self) {
        println!("=== end bench: {} ===", self.title);
    }
}

/// `std::hint::black_box` wrapper (stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Markdown-style table printer used by the table/figure benches so the
/// output can be pasted into EXPERIMENTS.md directly.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", fmt_row(&sep));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_ordered_quartiles() {
        std::env::set_var("HITGNN_BENCH_ITERS", "5");
        std::env::set_var("HITGNN_BENCH_WARMUP", "1");
        let mut b = Bench::new("unit");
        let m = b.measure("spin", |_| {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(m.p25_s <= m.median_s && m.median_s <= m.p75_s);
        assert!(m.median_s > 0.0);
        std::env::remove_var("HITGNN_BENCH_ITERS");
        std::env::remove_var("HITGNN_BENCH_WARMUP");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["dataset", "NVTPS"]);
        t.row(&["reddit".into(), "32.5 M".into()]);
        t.print();
    }
}
