//! Fixed-size bitset over `u64` words. Used for feature-store membership
//! (β computation touches it per sampled vertex — keep it branch-light).

#[derive(Clone, Debug, PartialEq)]
pub struct Bitset {
    words: Vec<u64>,
    len: usize,
}

impl Bitset {
    pub fn new(len: usize) -> Bitset {
        Bitset { words: vec![0; (len + 63) / 64], len }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over set indices.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = Bitset::new(130);
        assert!(!b.get(129));
        b.set(129);
        b.set(0);
        b.set(64);
        assert!(b.get(129) && b.get(0) && b.get(64));
        assert!(!b.get(1));
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn iter_ones_in_order() {
        let mut b = Bitset::new(200);
        for i in [3usize, 64, 65, 199] {
            b.set(i);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, vec![3, 64, 65, 199]);
    }

    #[test]
    fn empty_and_full() {
        let b = Bitset::new(0);
        assert_eq!(b.count(), 0);
        let mut f = Bitset::new(67);
        for i in 0..67 {
            f.set(i);
        }
        assert_eq!(f.count(), 67);
        assert_eq!(f.iter_ones().count(), 67);
    }
}
