//! Tiny command-line parser (the offline environment has no `clap`).
//!
//! Grammar: `prog [subcommand] [--flag] [--key value] [--key=value] [pos...]`.
//! Typed accessors record which keys were consumed so [`Args::finish`] can
//! reject typos instead of silently ignoring them.

use std::collections::{BTreeMap, BTreeSet};

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    /// First non-flag token, if any (subcommand).
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<BTreeSet<String>>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit token iterator.
    pub fn parse<I, S>(tokens: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut kv = BTreeMap::new();
        let mut flags = BTreeSet::new();
        let mut positional = Vec::new();
        let mut subcommand = None;
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    kv.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    kv.insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(stripped.to_string());
                }
            } else if subcommand.is_none() && positional.is_empty() {
                subcommand = Some(t.clone());
            } else {
                positional.push(t.clone());
            }
            i += 1;
        }
        Args {
            subcommand,
            kv,
            flags,
            positional,
            consumed: std::cell::RefCell::new(BTreeSet::new()),
        }
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().insert(key.to_string());
    }

    /// String option with default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.kv.get(key).cloned()
    }

    /// Required string option.
    pub fn req_str(&self, key: &str) -> anyhow::Result<String> {
        self.mark(key);
        self.kv
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("missing required option --{key}"))
    }

    /// Typed numeric option with default.
    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    /// Boolean flag (present / absent).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains(key)
    }

    /// Positional arguments (after the subcommand).
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error on unconsumed `--options` (typo protection). Call after all
    /// accessors.
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k.as_str()))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown option(s): {}", unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_kv_flags_positional() {
        let a = Args::parse(["train", "--epochs", "10", "--fast", "--out=run.json", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.num::<usize>("epochs", 1).unwrap(), 10);
        assert!(a.flag("fast"));
        assert_eq!(a.str("out", ""), "run.json");
        assert_eq!(a.positional(), ["extra".to_string()]);
        a.finish().unwrap();
    }

    #[test]
    fn defaults_and_missing() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.subcommand, None);
        assert_eq!(a.num::<f64>("lr", 0.1).unwrap(), 0.1);
        assert!(!a.flag("x"));
        assert!(a.req_str("needed").is_err());
    }

    #[test]
    fn bad_number_reports_key() {
        let a = Args::parse(["--n", "abc"]);
        let err = a.num::<usize>("n", 0).unwrap_err().to_string();
        assert!(err.contains("--n=abc"), "{err}");
    }

    #[test]
    fn finish_rejects_unknown() {
        let a = Args::parse(["cmd", "--typo", "1"]);
        assert!(a.finish().is_err());
        let b = Args::parse(["cmd", "--ok", "1"]);
        b.str("ok", "");
        b.finish().unwrap();
    }

    #[test]
    fn double_dash_value_styles_match() {
        let a = Args::parse(["--k=v"]);
        let b = Args::parse(["--k", "v"]);
        assert_eq!(a.str("k", ""), b.str("k", ""));
    }
}
