//! Minimal JSON parser / writer.
//!
//! Used for (1) the `artifacts/manifest.json` handshake written by the
//! Python AOT compiler and (2) run configuration files / result dumps.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP are passed through unchecked.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialisation is
/// deterministic (stable diffs in EXPERIMENTS.md).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    // ---- accessors -----------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Required-field helpers that produce good error messages.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json field '{key}' is not a string"))
    }
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json field '{key}' is not a non-negative integer"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json field '{key}' is not a number"))
    }

    // ---- parse ----------------------------------------------------------
    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Parse a JSON file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ---- write ----------------------------------------------------------
    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }
    /// Pretty serialisation with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (valid UTF-8 by construction)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| self.err("invalid utf-8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""A\t\\""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\\");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("hit\"gnn")),
            ("n", Json::num(16.0)),
            ("xs", Json::arr(vec![Json::num(1.5), Json::Bool(false), Json::Null])),
            ("empty", Json::Arr(vec![])),
        ]);
        for text in [v.to_string(), v.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn integers_serialise_without_fraction() {
        assert_eq!(Json::num(1024.0).to_string(), "1024");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn req_helpers_report_missing_fields() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.req_usize("a").unwrap(), 1);
        let err = v.req_str("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut v = Json::num(1.0);
        for _ in 0..100 {
            v = Json::arr(vec![v]);
        }
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}
