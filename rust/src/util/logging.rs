//! Leveled stderr logger with elapsed-time stamps.
//!
//! Controlled by `HITGNN_LOG` (error|warn|info|debug|trace; default info).
//! Deliberately tiny: the coordinator's hot loop logs nothing, so the
//! logger only needs to be convenient, not fast.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static START: OnceLock<Instant> = OnceLock::new();
static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let lvl = std::env::var("HITGNN_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (used by `--verbose` / tests).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether `level` is currently enabled.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

/// Core log call; prefer the macros.
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:10.3}s {} {module}] {msg}", level.tag());
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn set_level_gates_output() {
        set_level(Level::Error);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error);
        crate::log_info!("hidden {}", 1);
        crate::log_error!("shown {}", 2);
        set_level(Level::Info);
    }
}
