//! Substrate utilities built in-repo because the offline environment has no
//! access to `rand`, `serde`, `clap`, `criterion`, or `proptest`.
//!
//! - [`rng`]      — xoshiro256** + splitmix64 deterministic PRNG
//! - [`json`]     — minimal JSON parser / writer (manifest + config exchange)
//! - [`cli`]      — flag/subcommand parser for the launcher and benches
//! - [`stats`]    — running statistics, percentiles, geometric mean
//! - [`bench`]    — tiny criterion-style measurement harness
//! - [`logging`]  — leveled stderr logger with wall-clock timestamps
//! - [`proptest`] — miniature property-testing driver (random cases + seed
//!                  reporting on failure)
//! - `alloc`      — counting global allocator (feature `alloc-count`) for
//!                  the zero-allocation hot-path audit

#[cfg(feature = "alloc-count")]
pub mod alloc;
pub mod bench;
pub mod bitset;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
