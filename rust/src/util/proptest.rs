//! Miniature property-testing driver (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs. On
//! failure it panics with the case index and the *seed*, so the failing
//! input can be regenerated deterministically:
//!
//! ```no_run
//! use hitgnn::util::{proptest, rng::Rng};
//! proptest::check("sum commutes", 256, |rng| {
//!     let (a, b) = (rng.next_below(1000) as i64, rng.next_below(1000) as i64);
//!     proptest::require(a + b == b + a, &format!("{a} {b}"))
//! });
//! ```

use super::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert a condition inside a property.
pub fn require(cond: bool, detail: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(detail.to_string())
    }
}

/// Base seed: overridable via `HITGNN_PROP_SEED` to replay failures.
fn base_seed() -> u64 {
    std::env::var("HITGNN_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Number of cases multiplier: `HITGNN_PROP_CASES_SCALE` (default 1).
fn scale() -> usize {
    std::env::var("HITGNN_PROP_CASES_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Run `prop` on `cases` deterministic pseudo-random inputs. Each case gets
/// a child RNG seeded from (base_seed, case index), so a failure message
/// like "case 17" is reproducible in isolation.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> PropResult) {
    let seed = base_seed();
    let total = cases * scale();
    for case in 0..total {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(detail) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{total} \
                 (replay: HITGNN_PROP_SEED={seed}): {detail}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 32, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 32 * scale());
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 8, |rng| {
            require(rng.f64() < -1.0, "impossible")
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("record", 8, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("record", 8, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
