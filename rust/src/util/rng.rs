//! Deterministic PRNG: xoshiro256** seeded via splitmix64.
//!
//! Every stochastic component in the coordinator (graph generation,
//! partitioning tie-breaks, neighbor sampling) takes an explicit [`Rng`]
//! so that runs are reproducible from a single CLI `--seed`.

/// splitmix64 step — used for seeding and as a cheap stateless hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of `x` (splitmix64 finalizer). Used to derive
/// per-vertex deterministic features without materialising a stream.
#[inline]
pub fn hash64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. per worker / per partition).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ hash64(tag))
    }

    /// Snapshot the full 256-bit generator state (checkpoint/resume).
    #[inline]
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact saved stream position — the
    /// inverse of [`Rng::state`], so a resumed run continues the same
    /// draw sequence bit-for-bit.
    #[inline]
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit precision).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n) — Floyd's algorithm.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        self.sample_distinct_into(n, k, &mut out);
        out
    }

    /// [`Rng::sample_distinct`] into a caller-owned scratch vector
    /// (cleared first) — allocation-free once the scratch has capacity,
    /// which is what the sampler hot path needs. Membership is a linear
    /// scan of the chosen set (k is a fanout, ≤ a few dozen), consuming
    /// the exact same draw sequence and producing the exact same output
    /// order as the original HashSet-based implementation.
    pub fn sample_distinct_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        out.clear();
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let v = if out.contains(&t) { j } else { t };
            out.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let s = r.sample_distinct(50, 10);
            assert_eq!(s.len(), 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn sample_distinct_full() {
        let mut r = Rng::new(3);
        let mut s = r.sample_distinct(8, 8);
        s.sort_unstable();
        assert_eq!(s, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn hash64_is_stable() {
        assert_eq!(hash64(0), hash64(0));
        assert_ne!(hash64(1), hash64(2));
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
