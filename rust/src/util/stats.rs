//! Running statistics, percentiles, and the geometric mean used throughout
//! the benchmark harness and the EXPERIMENTS.md tables.

/// Online mean/variance accumulator (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Running {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample using linear interpolation. `q` in [0,1].
/// Sorts a copy; fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median shorthand.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Geometric mean (used for the paper's Table 6 "Geo. Mean" column).
/// All inputs must be positive.
pub fn geo_mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geo_mean of empty sample");
    let s: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geo_mean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (s / xs.len() as f64).exp()
}

/// Human-readable SI formatting for throughput numbers, matching the
/// paper's style ("97.0 M", "63.4 K").
pub fn si(x: f64) -> String {
    let (v, suffix) = if x.abs() >= 1e9 {
        (x / 1e9, " G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, " M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, " K")
    } else {
        (x, "")
    };
    if v.abs() >= 100.0 {
        format!("{v:.0}{suffix}")
    } else {
        format!("{v:.1}{suffix}")
    }
}

/// Duration formatting for the bench harness.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geo_mean_basic() {
        assert!((geo_mean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geo_mean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geo_mean_rejects_nonpositive() {
        geo_mean(&[1.0, 0.0]);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(97.0e6), "97.0 M");
        assert_eq!(si(63.4e3), "63.4 K");
        assert_eq!(si(313.0e3), "313 K");
        assert_eq!(si(12.0), "12.0");
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(5e-9), "5.0 ns");
    }
}
