//! Zero-allocation steady-state audit (ISSUE 5 + ISSUE 7 acceptance):
//! after warm-up, the training hot path must perform **zero** heap
//! allocations per iteration — first the sampler + feature-gather stage
//! alone (`comm::audit_sampler_gather_allocs`), then the *full*
//! iteration including batch assembly, p reference train steps into
//! recycled `GradBuffers`, the `GradReducer` sum, and the fused
//! optimizer step (`coordinator::audit::audit_full_iteration_allocs`).
//! Both protocols are shared with the `micro_host` kernel sweep so CI
//! and the bench can never measure different things.
//!
//! Only built with `--features alloc-count` (the counting global
//! allocator), and deliberately the only test in this binary: the
//! counter is process-wide, so concurrent test threads would pollute it.
#![cfg(feature = "alloc-count")]

use hitgnn::comm::audit_sampler_gather_allocs;
use hitgnn::coordinator::audit::audit_full_iteration_allocs;
use hitgnn::graph::datasets;
use hitgnn::partition::{preprocess, Algorithm};
use hitgnn::sampling::FanoutConfig;

#[test]
fn sampler_and_gather_steady_state_is_allocation_free() {
    let data = datasets::lookup("tiny").unwrap().build(0, 21);
    let pre = preprocess(Algorithm::DistDgl, &data, 2, 0.2, 21);
    let take = pre.train_parts[0].len().min(64);
    let targets = &pre.train_parts[0][..take];
    let iters = 32usize;
    let allocs = audit_sampler_gather_allocs(
        &data,
        pre.stores[0].as_ref(),
        pre.vertex_part.as_deref(),
        FanoutConfig::new(64, &[5, 3]),
        targets,
        9,
        4,
        iters,
    );
    assert_eq!(
        allocs, 0,
        "sampler+gather steady state allocated {allocs} times over {iters} iterations \
         ({} allocations/iteration)",
        allocs as f64 / iters as f64
    );

    // ISSUE 7 + ISSUE 8: the whole iteration — sample → gather → assemble
    // → p train steps (recycled GradBuffers) → serial reduce → fused SGD —
    // stays allocation-free once warm, for every model-zoo architecture
    // (the GAT attention lanes and GIN MLP lanes live in the same
    // workspace arena as the gcn/sage path).
    let iters = 16usize;
    for model in hitgnn::runtime::MODEL_NAMES {
        let allocs = audit_full_iteration_allocs(model, 2, 4, iters);
        assert_eq!(
            allocs, 0,
            "{model}: full training iteration allocated {allocs} times over {iters} \
             iterations ({} allocations/iteration)",
            allocs as f64 / iters as f64
        );
    }
}
