//! Failure injection: malformed manifests, missing artifacts, impossible
//! configurations, degenerate workloads — every failure must be a clean
//! error, never a panic or a silent wrong answer.

use std::path::PathBuf;

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::dse::DseEngine;
use hitgnn::fault::FaultPlan;
use hitgnn::fpga::parse_fleet;
use hitgnn::graph::datasets;
use hitgnn::partition::{preprocess, Algorithm};
use hitgnn::perf::PlatformSpec;
use hitgnn::runtime::Manifest;
use hitgnn::sampling::{FanoutConfig, Sampler, WeightMode};
use hitgnn::sched::SchedMode;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hitgnn_fail_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn manifest_failures_are_clean_errors() {
    // missing directory
    assert!(Manifest::load(&PathBuf::from("/nonexistent/dir")).is_err());

    // invalid json
    let dir = tmpdir("badjson");
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("manifest.json"), "{err}");

    // valid json, empty entries
    std::fs::write(dir.join("manifest.json"), r#"{"version":1,"entries":[]}"#).unwrap();
    assert!(Manifest::load(&dir).is_err());

    // entry pointing at a missing artifact file
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version":1,"entries":[{"name":"t","kind":"train","model":"gcn",
            "dataset":"tiny","file":"missing.hlo.txt","params":[],"outputs":["loss"],
            "dims":{"b":4,"k1":1,"k2":1,"v1_cap":8,"v0_cap":16,"f0":4,"f1":4,"f2":4}}]}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("missing"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

// Requires the real PJRT backend: the reference executor never parses HLO
// text, so a corrupt artifact file cannot fail there.
#[cfg(feature = "pjrt")]
#[test]
fn corrupt_hlo_text_fails_at_compile_not_execute() {
    let dir = tmpdir("badhlo");
    std::fs::write(dir.join("garbage.hlo.txt"), "HloModule nope\nENTRY oops {}").unwrap();
    let entry = hitgnn::runtime::ArtifactEntry {
        name: "garbage".into(),
        kind: "train".into(),
        model: "gcn".into(),
        dataset: "tiny".into(),
        path: dir.join("garbage.hlo.txt"),
        dims: hitgnn::runtime::ArtifactDims::from_batch(4, &[1, 1], &[4, 4, 4]),
        params: vec![],
        outputs: vec!["loss".into()],
    };
    assert!(hitgnn::runtime::TrainExecutor::compile(&entry).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// The reference-backend twin of the corrupt-HLO test: an artifact that
// does not match a known model architecture fails at compile time, not
// mid-epoch.
#[cfg(not(feature = "pjrt"))]
#[test]
fn unknown_architecture_fails_at_compile_not_execute() {
    let m = Manifest::builtin(&PathBuf::from("/nonexistent"));
    let mut entry = m.find("train", "gcn", "tiny").unwrap().clone();
    entry.model = "gat".into();
    assert!(hitgnn::runtime::TrainExecutor::compile(&entry).is_err());
    let mut entry = m.find("train", "gcn", "tiny").unwrap().clone();
    entry.params.pop(); // wrong arity
    assert!(hitgnn::runtime::TrainExecutor::compile(&entry).is_err());
}

#[test]
fn trainer_falls_back_to_builtin_manifest_and_rejects_bad_dataset() {
    // A missing artifacts dir is no longer fatal: the coordinator falls
    // back to the builtin manifest + reference executor (DESIGN.md
    // §Execution backends), so training works out of the box.
    let cfg = TrainConfig {
        dataset: "tiny".into(),
        num_fpgas: 2,
        max_iterations: Some(1),
        artifacts_dir: PathBuf::from("/nonexistent"),
        ..TrainConfig::default()
    };
    #[cfg(not(feature = "pjrt"))]
    Trainer::new(cfg).expect("builtin-manifest fallback must work").shutdown();
    // with the pjrt feature the missing artifacts are still a clean error
    #[cfg(feature = "pjrt")]
    assert!(Trainer::new(cfg).is_err());

    let cfg = TrainConfig { dataset: "not-a-dataset".into(), ..TrainConfig::default() };
    assert!(Trainer::new(cfg).is_err());

    let cfg = TrainConfig { model: "not-a-model".into(), ..TrainConfig::default() };
    assert!(Trainer::new(cfg).is_err());
}

#[test]
fn trainer_rejects_artifact_dataset_dim_mismatch() {
    // ask for the reddit artifact against the tiny dataset name — the
    // manifest lookup is by dataset, so spoof via a config whose dataset
    // has no artifact
    let cfg = TrainConfig {
        dataset: "amazon".into(), // artifacts exist, but graph build at
        scale_shift: 10,          // heavy shift keeps this test fast
        num_fpgas: 2,
        epochs: 1,
        max_iterations: Some(1),
        ..TrainConfig::default()
    };
    // this should actually succeed structurally (artifact exists); the
    // mismatch case is a *wrong* manifest — simulate by env-pointing at a
    // manifest without amazon
    let r = Trainer::new(cfg);
    // Either works (artifacts built for amazon) or fails cleanly — never
    // panics. Just exercise the path:
    match r {
        Ok(t) => t.shutdown(),
        Err(e) => {
            let msg = e.to_string();
            assert!(!msg.is_empty());
        }
    }
}

#[test]
fn dse_with_impossible_resources_errors() {
    let mut spec = PlatformSpec::paper_4fpga();
    // an FPGA with essentially no resources
    spec.fpga.dsp_per_die = 1;
    spec.fpga.lut_per_die = 1;
    spec.fpga.uram_per_die = 1;
    spec.fpga.bram_per_die = 1;
    let engine = DseEngine::new(spec);
    let workloads = hitgnn::dse::paper_dse_workloads(1.0);
    assert!(engine.explore(&workloads).is_err(), "no feasible point must be an error");
}

#[test]
fn empty_partitions_are_tolerated() {
    // p close to |train| so some partitions may be nearly empty; the
    // scheduler + plan must still terminate and cover everything
    let d = datasets::lookup("tiny").unwrap().build(2, 5);
    let pre = preprocess(Algorithm::P3, &d, 7, 0.2, 5);
    let counts: Vec<usize> = (0..7).map(|i| pre.batches_in_part(i, 64)).collect();
    let mut sched = hitgnn::sched::TwoStageScheduler::new(7, true);
    let plans = sched.plan_epoch(&counts);
    let total: usize = plans.iter().map(|p| p.tasks.len()).sum();
    assert_eq!(total, counts.iter().sum::<usize>());
}

#[test]
fn sampler_handles_isolated_vertices() {
    // a graph with isolated vertices: neighbor lists empty → batches must
    // still validate (self edge only)
    use hitgnn::graph::{Csr, FeatureGen};
    let spec = datasets::lookup("tiny").unwrap();
    let mut d = spec.build(0, 3);
    // overwrite with an almost-empty graph
    d.graph = Csr::from_edges(d.graph.num_vertices(), &[(0, 1), (1, 0)]);
    d.features = FeatureGen::new(3, spec.dims.f0, spec.dims.f2);
    let cfg = FanoutConfig::new(8, &[3, 2]);
    let mut s = Sampler::new(cfg, WeightMode::GcnNorm, d.graph.num_vertices(), 1);
    let targets: Vec<u32> = (0..8u32).collect();
    let mb = s.sample(&d, &targets, 0, 0);
    mb.validate().unwrap();
    // isolated targets aggregate only themselves
    assert!(mb.n[0] >= mb.n_targets());
}

#[test]
fn zero_capacity_cache_still_trains_accounting() {
    // PaGraph with cache_ratio 0: everything is a miss; traffic must be
    // 100% remote, beta == 0
    let d = datasets::lookup("tiny").unwrap().build(0, 9);
    let pre = preprocess(Algorithm::PaGraph, &d, 2, 0.0, 9);
    let cfg = FanoutConfig::new(16, &[2, 2]);
    let mut s = Sampler::new(cfg, WeightMode::GcnNorm, d.graph.num_vertices(), 2);
    let mb = s.sample(&d, &pre.train_parts[0][..16], 0, 0);
    let t = hitgnn::comm::feature_traffic(
        &mb,
        pre.stores[0].as_ref(),
        d.features.bytes_per_vertex(),
        hitgnn::comm::CommConfig::default(),
        pre.vertex_part.as_deref(),
        0,
    );
    assert_eq!(t.local_bytes, 0);
    assert_eq!(t.beta(), 0.0);
}

#[test]
fn prep_worker_errors_propagate_instead_of_panicking() {
    // ISSUE 5 satellite: a failure inside a prep worker (here: a task
    // whose target list exceeds the batch capacity, which panics in the
    // sampler) must surface to the coordinator as a clean `Err` — not a
    // poisoned thread join — and the worker must keep serving tasks.
    use hitgnn::coordinator::prep::{drain_prepared, prep_worker, PrepTask};
    use std::sync::{mpsc, Mutex};

    let d = datasets::lookup("tiny").unwrap().build(0, 11);
    let pre = preprocess(Algorithm::DistDgl, &d, 2, 0.2, 11);
    let cfg = FanoutConfig::new(8, &[3, 2]); // batch capacity 8
    let mut sampler = Sampler::new(cfg, WeightMode::GcnNorm, d.graph.num_vertices(), 1);
    let good: Vec<u32> = pre.train_parts[0][..8.min(pre.train_parts[0].len())].to_vec();

    let (task_tx, task_rx) = mpsc::channel();
    let (done_tx, done_rx) = mpsc::channel();
    let oversized: Vec<u32> = (0..64u32).collect();
    task_tx
        .send(PrepTask {
            iter: 0,
            tag: 0,
            part: 0,
            fpga: 0,
            seq: 0,
            targets: oversized,
            inject_panic: false,
        })
        .unwrap();
    task_tx
        .send(PrepTask {
            iter: 0,
            tag: 1,
            part: 0,
            fpga: 0,
            seq: 1,
            targets: good,
            inject_panic: false,
        })
        .unwrap();
    drop(task_tx);

    let rx = Mutex::new(task_rx);
    let snaps = pre.residency_snapshot();
    std::thread::scope(|s| {
        let done_tx = done_tx.clone();
        let rxr = &rx;
        let data = &d;
        let stores = &snaps[..];
        let vertex_part = pre.vertex_part.as_deref();
        let smp = &mut sampler;
        s.spawn(move || {
            prep_worker(
                data,
                stores,
                vertex_part,
                smp,
                hitgnn::comm::CommConfig::default(),
                3,
                rxr,
                &done_tx,
                None,
            )
        });
    });
    drop(done_tx);

    let results: Vec<_> = done_rx.iter().collect();
    assert_eq!(results.len(), 2, "both tasks must produce a result");
    match &results[0] {
        Err(e) => {
            let msg = e.to_string();
            assert!(msg.contains("iter 0 tag 0"), "{msg}");
        }
        Ok(_) => panic!("oversized batch must surface as Err"),
    }
    assert!(results[1].is_ok(), "worker must keep serving after an error");

    // the drain helper propagates worker errors to the caller
    let (tx, rx) = mpsc::channel();
    tx.send(Err(anyhow::anyhow!("injected prep failure"))).unwrap();
    drop(tx);
    assert!(drain_prepared(&rx).is_err());
}

#[test]
fn trainer_surfaces_prep_failures_as_errors_not_hangs() {
    // end-to-end twin of the case above: poison a partition with an
    // out-of-range vertex id so a prep worker panics mid-epoch inside a
    // fully pipelined run. The coordinator must come back with a clean
    // `Err` (winding the pool down), not hang on the prefetch window or
    // re-raise the panic through the scoped join.
    let cfg = TrainConfig {
        dataset: "tiny".into(),
        num_fpgas: 2,
        epochs: 1,
        scale_shift: 0,
        host_threads: 2,
        prefetch_depth: 2,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).expect("trainer builds");
    let bogus = t.data.graph.num_vertices() as u32 + 1_000;
    t.pre.train_parts[0][0] = bogus; // sampler will index out of range
    let err = t.run().expect_err("poisoned partition must fail the epoch");
    let msg = format!("{err:#}");
    assert!(msg.contains("prep worker panicked"), "{msg}");
}

#[test]
fn cli_rejects_malformed_invocations() {
    use hitgnn::coordinator::cli::run;
    use hitgnn::util::cli::Args;
    assert!(run(&Args::parse(["definitely-not-a-subcommand"])).is_err());
    assert!(run(&Args::parse(["train", "--fpgas", "zero"])).is_err());
    assert!(run(&Args::parse(["simulate", "--typo-flag", "1"])).is_err());
    assert!(run(&Args::parse(["dse", "--model"])).is_ok() || true); // flag-style --model consumed safely
}

#[test]
fn fanout_config_rejects_degenerate_values_at_every_entry_point() {
    use hitgnn::coordinator::cli::run;
    use hitgnn::util::cli::Args;
    // library entry point
    assert!(FanoutConfig::new(0, &[5]).validate().is_err());
    assert!(FanoutConfig::new(32, &[]).validate().is_err());
    assert!(FanoutConfig::new(32, &[5, 0]).validate().is_err());
    assert!(FanoutConfig::new(1024, &[63, 63, 63, 63]).validate().is_err(), "memory bound");
    // CLI entry point: rejected at parse, before any training state
    assert!(run(&Args::parse(["train", "--fanouts", "0,5"])).is_err());
    assert!(run(&Args::parse(["train", "--fanouts", "abc"])).is_err());
    assert!(run(&Args::parse(["train", "--fanouts", ""])).is_err());
    // trainer entry point: the level-0 memory bound uses the artifact's
    // batch size (tiny b=32 × these fanouts blows the cap)
    let cfg = TrainConfig {
        dataset: "tiny".into(),
        fanouts: Some(vec![127, 127, 127, 127]),
        num_fpgas: 2,
        scale_shift: 0,
        ..TrainConfig::default()
    };
    let err = Trainer::new(cfg).unwrap_err().to_string();
    assert!(err.contains("level-0 capacity"), "{err}");
}

// ---------------------------------------------------------------------
// --fault-plan: the deterministic fault-injection harness
// (DESIGN.md §Fault tolerance)
// ---------------------------------------------------------------------

fn fault_cfg(plan: Option<&str>) -> TrainConfig {
    TrainConfig {
        dataset: "tiny".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 2,
        epochs: 2,
        scale_shift: 0,
        seed: 21,
        max_iterations: Some(6),
        fault_plan: plan.map(|s| FaultPlan::parse(s).expect("test plan parses")),
        ..TrainConfig::default()
    }
}

#[test]
fn injected_prep_panic_aborts_cleanly_and_the_pool_survives() {
    // ISSUE 10 satellite: a prep-worker panic mid-epoch must surface as a
    // clean `Err` — the coordinator drains the prep/recycle channels and
    // joins the workers — and the *same* Trainer (same WorkerPool, same
    // recycle channel) must run the next epoch cleanly. Deep prefetch
    // window + several host threads so batches are genuinely in flight
    // when the panic lands.
    let mut cfg = fault_cfg(Some("prep:panic@e0i1"));
    cfg.host_threads = 2;
    cfg.prefetch_depth = 3;
    let mut t = Trainer::new(cfg).expect("plan validates against fleet and run");
    let err = t.run_epoch(0).expect_err("injected panic must fail the epoch");
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "{msg}");
    // same pool, next epoch: nothing leaked, nothing poisoned, no hang
    let m = t.run_epoch(1).expect("pool must survive an injected failure");
    assert!(m.iterations > 0);
    assert!(m.iter_losses.iter().all(|l| l.is_finite()));
    t.shutdown();
}

#[test]
fn device_loss_completes_the_epoch_with_every_batch_trained_once() {
    // ISSUE 10 acceptance: on a heterogeneous u250:2,u250-half:2 fleet, a
    // device lost mid-epoch quarantines and its work reroutes to the
    // survivors — the run completes, trains exactly as many batches as the
    // healthy run, and reports the quarantine/reassignment counters. Both
    // scheduler modes.
    for mode in SchedMode::ALL {
        let cfg = |plan: Option<&str>| {
            let mut c = fault_cfg(plan);
            c.num_fpgas = 4;
            c.fleet = Some(parse_fleet("u250:2,u250-half:2").unwrap());
            c.sched = mode;
            c.max_iterations = None; // full epochs: the tail is where reroutes land
            c
        };
        let run = |c: TrainConfig| {
            let mut t = Trainer::new(c).unwrap();
            let r = t.run().unwrap();
            t.shutdown();
            r
        };
        let healthy = run(cfg(None));
        let faulted = run(cfg(Some("dev1:fail@e0i1")));
        assert_eq!(healthy.epochs.len(), faulted.epochs.len());
        for (h, f) in healthy.epochs.iter().zip(&faulted.epochs) {
            // exactly-once: the degraded epoch still trains every batch
            assert_eq!(h.batches, f.batches, "{mode:?} epoch {}: batch count moved", h.epoch);
            assert!(f.iter_losses.iter().all(|l| l.is_finite()));
        }
        assert_eq!(faulted.epochs[0].quarantined_devices, 1, "{mode:?}");
        // the loss stays quarantined in later epochs, where *all* of the
        // dead device's batches are reassignments
        assert_eq!(faulted.epochs[1].quarantined_devices, 1, "{mode:?}");
        assert!(faulted.epochs[1].reassigned_batches > 0, "{mode:?}");
        for h in &healthy.epochs {
            assert_eq!(h.quarantined_devices, 0);
            assert_eq!(h.reassigned_batches, 0);
        }
        // same plan + same seed ⇒ bit-identical degraded run
        let again = run(cfg(Some("dev1:fail@e0i1")));
        for (a, b) in faulted.epochs.iter().zip(&again.epochs) {
            assert_eq!(a.iter_losses, b.iter_losses, "{mode:?}: faulted run not deterministic");
            assert_eq!(a.reassigned_batches, b.reassigned_batches);
        }
    }
}

#[test]
fn straggler_slowdown_reprices_the_cost_model_not_the_losses() {
    // `devN:slow*M@eE` multiplies the device's §6.2 per-batch seconds.
    // `--sched cost` then routes stage-2 extras around the straggler: its
    // modeled makespan under the *same priced cost model* is never worse
    // than batch-count assignment. The loss sequence — a function of the
    // partition stream alone — must not move at all.
    let cfg = |mode: SchedMode, plan: Option<&str>| {
        let mut c = fault_cfg(plan);
        c.fleet = Some(parse_fleet("u250:1,u250-half:1").unwrap());
        c.sched = mode;
        c.epochs = 1;
        c.max_iterations = None;
        c
    };
    let run = |c: TrainConfig| {
        let mut t = Trainer::new(c).unwrap();
        let r = t.run().unwrap();
        t.shutdown();
        r
    };
    let plan = "dev0:slow*8@e0";
    let healthy = run(cfg(SchedMode::Cost, None));
    let slow_cost = run(cfg(SchedMode::Cost, Some(plan)));
    let slow_batch = run(cfg(SchedMode::BatchCount, Some(plan)));
    let losses = |r: &hitgnn::coordinator::TrainReport| r.epochs[0].iter_losses.clone();
    assert_eq!(losses(&healthy), losses(&slow_cost), "slowdown must not touch the numerics");
    assert_eq!(losses(&healthy), losses(&slow_batch));
    // the straggler makes the modeled epoch strictly slower...
    assert!(
        slow_cost.epochs[0].epoch_makespan_seconds > healthy.epochs[0].epoch_makespan_seconds,
        "slow {} !> healthy {}",
        slow_cost.epochs[0].epoch_makespan_seconds,
        healthy.epochs[0].epoch_makespan_seconds
    );
    // ...and cost-aware assignment visibly routes around it
    assert!(
        slow_cost.epochs[0].epoch_makespan_seconds
            <= slow_batch.epochs[0].epoch_makespan_seconds + 1e-9,
        "cost {} worse than batch-count {}",
        slow_cost.epochs[0].epoch_makespan_seconds,
        slow_batch.epochs[0].epoch_makespan_seconds
    );
}

#[test]
fn transient_disk_errors_retry_deterministically_and_stay_loss_invariant() {
    // `disk:eio@p` draws per-(epoch, iter, tag, attempt) from a stateless
    // hash — no RNG stream is consumed, so the retried run's numerics are
    // bit-identical to the healthy run's, and the retry count itself is
    // reproducible.
    let run = |c: TrainConfig| {
        let mut t = Trainer::new(c).unwrap();
        let r = t.run().unwrap();
        t.shutdown();
        r
    };
    let healthy = run(fault_cfg(None));
    let faulted = run(fault_cfg(Some("disk:eio@0.5")));
    let losses = |r: &hitgnn::coordinator::TrainReport| -> Vec<f64> {
        r.epochs.iter().flat_map(|e| e.iter_losses.iter().copied()).collect()
    };
    assert_eq!(losses(&healthy), losses(&faulted), "retries must not touch the numerics");
    let retries: u64 = faulted.epochs.iter().map(|e| e.disk_retries).sum();
    assert!(retries > 0, "p=0.5 over 24 batch draws must hit at least once");
    assert_eq!(healthy.epochs.iter().map(|e| e.disk_retries).sum::<u64>(), 0);
    let again = run(fault_cfg(Some("disk:eio@0.5")));
    assert_eq!(
        retries,
        again.epochs.iter().map(|e| e.disk_retries).sum::<u64>(),
        "retry count must be a pure function of (plan, seed)"
    );
}

#[test]
fn persistent_disk_errors_exhaust_retries_into_a_clean_fatal_error() {
    // p = 1: every attempt fails, so the bounded retry gives up after
    // DISK_RETRY_MAX with a clean error naming the batch — never a hang
    // or a panic.
    let mut t = Trainer::new(fault_cfg(Some("disk:eio@1"))).unwrap();
    let err = t.run().expect_err("certain disk failure must be fatal");
    let msg = format!("{err:#}");
    assert!(msg.contains("disk read failed"), "{msg}");
    assert!(msg.contains("--fault-plan disk:eio"), "{msg}");
    // and the trainer still winds down cleanly
    t.shutdown();
}

#[test]
fn fault_plans_are_validated_against_the_live_run() {
    // unknown device id — rejected at construction, naming the device
    let err = Trainer::new(fault_cfg(Some("dev9:fail@e0i0"))).unwrap_err().to_string();
    assert!(err.contains("dev9"), "{err}");
    // epoch anchor past the end of the run
    let err = Trainer::new(fault_cfg(Some("dev0:fail@e5i0"))).unwrap_err().to_string();
    assert!(err.contains("e5i0") && err.contains("2 epochs"), "{err}");
    // killing the whole fleet leaves no survivors
    let err =
        Trainer::new(fault_cfg(Some("dev0:fail@e0i0,dev1:fail@e0i0"))).unwrap_err().to_string();
    assert!(err.contains("no survivors"), "{err}");
    // iteration anchors are checked by the planner (first place the
    // iteration count exists) — out of range is a clean error, not a
    // silently ignored fault
    let mut t = Trainer::new(fault_cfg(Some("prep:panic@e0i999"))).unwrap();
    let err = t.run().unwrap_err().to_string();
    assert!(err.contains("e0i999") && err.contains("out of range"), "{err}");
    t.shutdown();
}

#[test]
fn corrupt_checkpoints_are_clean_resume_errors() {
    // ISSUE 10 satellite: truncated, bit-flipped, and wrong-version
    // checkpoint files must all fail `--resume` with a clean `Err` that
    // names the problem — never a panic — and fingerprint mismatches are
    // caught before any state is overwritten.
    let dir = tmpdir("ckpt_corrupt");
    let mut cfg = fault_cfg(None);
    cfg.checkpoint_dir = Some(dir.clone());
    let mut t = Trainer::new(cfg.clone()).unwrap();
    t.run().unwrap();
    t.shutdown();
    let latest = hitgnn::fault::checkpoint::latest_in_dir(&dir).unwrap();
    let bytes = std::fs::read(&latest).unwrap();

    let resume_cfg = |path: &std::path::Path| {
        let mut c = fault_cfg(None);
        c.epochs = 4; // past the checkpoint's epoch_next = 2
        c.resume = Some(path.display().to_string());
        c
    };
    // the intact file resumes fine (directory resolution included)
    Trainer::new(resume_cfg(&dir)).expect("healthy resume").shutdown();

    // truncation at an arbitrary cut (a name outside the ckpt-e*.hitg
    // glob so directory resolution below still finds the intact file)
    let bad = dir.join("corrupt.hitg");
    std::fs::write(&bad, &bytes[..bytes.len() / 2]).unwrap();
    let err = Trainer::new(resume_cfg(&bad)).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");

    // flipped magic
    let mut flipped = bytes.clone();
    flipped[0] ^= 0xff;
    std::fs::write(&bad, &flipped).unwrap();
    let err = Trainer::new(resume_cfg(&bad)).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // future format version
    let mut wrong_v = bytes.clone();
    wrong_v[8] = 9; // version field sits right after the 8-byte magic
    std::fs::write(&bad, &wrong_v).unwrap();
    let err = Trainer::new(resume_cfg(&bad)).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // fingerprint mismatches: wrong model, wrong seed, epochs not raised
    let mut c = resume_cfg(&dir);
    c.model = "gin".into();
    let err = Trainer::new(c).unwrap_err().to_string();
    assert!(err.contains("checkpoint is for"), "{err}");
    let mut c = resume_cfg(&dir);
    c.seed = 99;
    let err = Trainer::new(c).unwrap_err().to_string();
    assert!(err.contains("seed"), "{err}");
    let mut c = resume_cfg(&dir);
    c.epochs = 2; // checkpoint already covers 2 epochs
    let err = Trainer::new(c).unwrap_err().to_string();
    assert!(err.contains("already covers"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_rejects_zero_and_empty_fanouts() {
    let dir = tmpdir("badfanout");
    std::fs::write(dir.join("t.hlo.txt"), "HloModule t").unwrap();
    for dims in [
        r#"{"b":4,"fanouts":[3,0],"f":[4,4,4]}"#,
        r#"{"b":4,"fanouts":[],"f":[4]}"#,
        r#"{"b":0,"fanouts":[3],"f":[4,4]}"#,
    ] {
        let manifest = format!(
            r#"{{"version":1,"entries":[{{"name":"t","kind":"train","model":"gcn",
                "dataset":"tiny","file":"t.hlo.txt","params":[],"outputs":["loss"],
                "dims":{dims}}}]}}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        assert!(Manifest::load(&dir).is_err(), "dims {dims} accepted");
    }
    std::fs::remove_dir_all(&dir).ok();
}
