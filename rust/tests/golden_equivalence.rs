//! Golden-equivalence guard (ISSUE 4): the depth-L generalization at
//! `fanouts = [k1, k2]` must be a provable no-op against the seed's
//! 2-layer behaviour — bit-identical `MiniBatch` contents and
//! bit-identical per-iteration training losses for the same seed on the
//! same dataset.
//!
//! The oracle below is the seed's 2-layer `Sampler::sample` transcribed
//! verbatim (same scratch structures, same RNG keying, same draw order),
//! so any reordering of RNG consumption or dedup bookkeeping in the
//! generalized level loop fails this test bit-exactly.

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::graph::{Csr, Dataset};
use hitgnn::partition::Algorithm;
use hitgnn::sampling::{FanoutConfig, MiniBatch, Sampler, WeightMode};
use hitgnn::util::rng::{hash64, Rng};

/// The seed's 2-layer sampler, kept as the golden oracle.
struct SeedSampler {
    batch_size: usize,
    k1: usize,
    k2: usize,
    mode: WeightMode,
    stream: u64,
    rng: Rng,
    stamp: Vec<u32>,
    pos: Vec<i32>,
    tag: u32,
    pick: Vec<u32>,
}

/// The seed's flat 2-layer batch (field names as in the seed).
struct SeedBatch {
    n_targets: usize,
    n_v1: usize,
    n_v0: usize,
    v2: Vec<u32>,
    v1: Vec<u32>,
    v0: Vec<u32>,
    idx1: Vec<i32>,
    w1: Vec<f32>,
    idx2: Vec<i32>,
    w2: Vec<f32>,
    labels: Vec<u32>,
    mask: Vec<f32>,
}

impl SeedSampler {
    fn new(batch_size: usize, k1: usize, k2: usize, mode: WeightMode, nv: usize, seed: u64) -> Self {
        SeedSampler {
            batch_size,
            k1,
            k2,
            mode,
            stream: seed,
            rng: Rng::new(seed),
            stamp: vec![0; nv],
            pos: vec![0; nv],
            tag: 0,
            pick: Vec::new(),
        }
    }

    fn sample(&mut self, data: &Dataset, targets: &[u32], part_id: usize, seq: usize) -> SeedBatch {
        self.rng = Rng::new(hash64(self.stream ^ ((part_id as u64) << 32) ^ (seq as u64)));
        let b = self.batch_size;
        let v1_cap = b * (self.k2 + 1);
        let v0_cap = v1_cap * (self.k1 + 1);
        assert!(targets.len() <= b);
        let g = &data.graph;
        let n_targets = targets.len();

        // ---- layer 2: targets → v1 --------------------------------------
        let mut v2 = vec![0u32; b];
        v2[..n_targets].copy_from_slice(targets);
        self.tag += 1;
        let mut v1: Vec<u32> = Vec::with_capacity(v1_cap);
        for &t in targets {
            self.place(t, &mut v1);
        }
        let mut idx2 = vec![0i32; b * (self.k2 + 1)];
        let mut w2 = vec![0f32; b * (self.k2 + 1)];
        for (r, &t) in targets.iter().enumerate() {
            let row = r * (self.k2 + 1);
            let self_pos = self.pos[t as usize];
            idx2[row] = self_pos;
            let k_real = self.sample_neighbors(g, t, self.k2);
            let picks = std::mem::take(&mut self.pick);
            w2[row] = self.self_weight(g, t);
            for (c, &u) in picks.iter().enumerate() {
                let p = self.place(u, &mut v1);
                idx2[row + 1 + c] = p;
                w2[row + 1 + c] = self.neighbor_weight(g, t, u, k_real);
            }
            self.pick = picks;
        }
        let n_v1 = v1.len();

        // ---- layer 1: v1 → v0 --------------------------------------------
        self.tag += 1;
        let mut v0: Vec<u32> = Vec::with_capacity(v0_cap);
        for &v in &v1 {
            self.place(v, &mut v0);
        }
        let mut idx1 = vec![0i32; v1_cap * (self.k1 + 1)];
        let mut w1 = vec![0f32; v1_cap * (self.k1 + 1)];
        for r in 0..n_v1 {
            let v = v1[r];
            let row = r * (self.k1 + 1);
            idx1[row] = self.pos[v as usize];
            let k_real = self.sample_neighbors(g, v, self.k1);
            let picks = std::mem::take(&mut self.pick);
            w1[row] = self.self_weight(g, v);
            for (c, &u) in picks.iter().enumerate() {
                let p = self.place(u, &mut v0);
                idx1[row + 1 + c] = p;
                w1[row + 1 + c] = self.neighbor_weight(g, v, u, k_real);
            }
            self.pick = picks;
        }
        let n_v0 = v0.len();

        // ---- labels / mask ------------------------------------------------
        let mut labels = vec![0u32; b];
        let mut mask = vec![0f32; b];
        for (r, &t) in targets.iter().enumerate() {
            labels[r] = data.features.label(t);
            mask[r] = 1.0;
        }
        v1.resize(v1_cap, 0);
        v0.resize(v0_cap, 0);
        SeedBatch { n_targets, n_v1, n_v0, v2, v1, v0, idx1, w1, idx2, w2, labels, mask }
    }

    fn place(&mut self, v: u32, list: &mut Vec<u32>) -> i32 {
        let vi = v as usize;
        if self.stamp[vi] == self.tag {
            return self.pos[vi];
        }
        self.stamp[vi] = self.tag;
        let p = list.len() as i32;
        self.pos[vi] = p;
        list.push(v);
        p
    }

    fn sample_neighbors(&mut self, g: &Csr, v: u32, k: usize) -> usize {
        let nbrs = g.neighbors(v);
        self.pick.clear();
        if nbrs.is_empty() {
            return 0;
        }
        if nbrs.len() <= k {
            self.pick.extend_from_slice(nbrs);
        } else {
            let idxs = self.rng.sample_distinct(nbrs.len(), k);
            self.pick.extend(idxs.into_iter().map(|i| nbrs[i]));
        }
        self.pick.len()
    }

    fn self_weight(&self, g: &Csr, v: u32) -> f32 {
        match self.mode {
            WeightMode::GcnNorm => 1.0 / (g.degree(v) as f32 + 1.0),
            WeightMode::SageMean | WeightMode::Unit => 1.0,
        }
    }

    fn neighbor_weight(&self, g: &Csr, v: u32, u: u32, k_real: usize) -> f32 {
        match self.mode {
            WeightMode::GcnNorm => {
                1.0 / (((g.degree(v) as f32 + 1.0) * (g.degree(u) as f32 + 1.0)).sqrt())
            }
            WeightMode::SageMean => 1.0 / k_real as f32,
            WeightMode::Unit => 1.0,
        }
    }
}

fn assert_bit_identical(mb: &MiniBatch, seed: &SeedBatch, tag: &str) {
    assert_eq!(mb.layers(), 2, "{tag}");
    assert_eq!(mb.n[2], seed.n_targets, "{tag}: n_targets");
    assert_eq!(mb.n[1], seed.n_v1, "{tag}: n_v1");
    assert_eq!(mb.n[0], seed.n_v0, "{tag}: n_v0");
    assert_eq!(mb.v[2], seed.v2, "{tag}: v2");
    assert_eq!(mb.v[1], seed.v1, "{tag}: v1");
    assert_eq!(mb.v[0], seed.v0, "{tag}: v0");
    assert_eq!(mb.idx[0], seed.idx1, "{tag}: idx1");
    assert_eq!(mb.idx[1], seed.idx2, "{tag}: idx2");
    // weights compared bit-exactly, not approximately
    let bits = |w: &[f32]| w.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&mb.w[0]), bits(&seed.w1), "{tag}: w1");
    assert_eq!(bits(&mb.w[1]), bits(&seed.w2), "{tag}: w2");
    assert_eq!(mb.labels, seed.labels, "{tag}: labels");
    assert_eq!(bits(&mb.mask), bits(&seed.mask), "{tag}: mask");
}

#[test]
fn generalized_sampler_is_bit_identical_to_seed_at_depth_two() {
    let data = hitgnn::graph::datasets::lookup("reddit").unwrap().build(8, 17);
    let nv = data.graph.num_vertices();
    for (mode, rng_seed) in [
        (WeightMode::GcnNorm, 7u64),
        (WeightMode::SageMean, 23u64),
        (WeightMode::Unit, 41u64),
    ] {
        let mut gen = Sampler::new(FanoutConfig::new(64, &[5, 3]), mode, nv, rng_seed);
        let mut oracle = SeedSampler::new(64, 5, 3, mode, nv, rng_seed);
        // several (part, seq) keys, including a short final batch, and in
        // an order that exercises the persistent stamp/pos scratch reuse
        let cases: [(usize, usize, usize, usize); 4] =
            [(0, 0, 0, 64), (1, 5, 64, 128), (0, 1, 128, 192), (2, 0, 300, 310)];
        for (part, seq, lo, hi) in cases {
            let targets: Vec<u32> = data.train_vertices[lo..hi].to_vec();
            let mb = gen.sample(&data, &targets, part, seq);
            let sb = oracle.sample(&data, &targets, part, seq);
            mb.validate().unwrap();
            assert_bit_identical(&mb, &sb, &format!("{mode:?} part={part} seq={seq}"));
        }
    }
}

/// (per-iteration losses, traffic totals) of a short tiny-dataset run.
fn run_losses(model: &str, fanouts: Option<Vec<usize>>) -> (Vec<f64>, (u64, u64, u64, u64)) {
    let cfg = TrainConfig {
        dataset: "tiny".into(),
        model: model.into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 2,
        epochs: 2,
        lr: 0.3,
        momentum: 0.9,
        scale_shift: 0,
        seed: 33,
        max_iterations: Some(6),
        fanouts,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    let losses: Vec<f64> =
        r.epochs.iter().flat_map(|e| e.iter_losses.iter().copied()).collect();
    let traffic = r.epochs.iter().fold((0u64, 0u64, 0u64, 0u64), |acc, e| {
        (
            acc.0 + e.local_bytes,
            acc.1 + e.host_bytes,
            acc.2 + e.f2f_bytes,
            acc.3 + e.dedup_saved_bytes,
        )
    });
    t.shutdown();
    (losses, traffic)
}

#[test]
fn explicit_default_fanouts_reproduce_the_seed_training_run() {
    // `--fanouts 3,2` (the tiny artifact's own fanouts) must take the
    // exact same path as no override: bit-identical per-iteration losses
    // and Traffic totals — the refactor is a no-op at L = 2.
    let base = run_losses("gcn", None);
    let explicit = run_losses("gcn", Some(vec![3, 2]));
    assert!(!base.0.is_empty());
    assert_eq!(
        base.0.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        explicit.0.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "losses diverged between default and explicit [3, 2] fanouts"
    );
    assert_eq!(base.1, explicit.1, "traffic diverged");
}

#[test]
fn model_zoo_training_runs_are_bit_stable_end_to_end() {
    // ISSUE 8 golden guard across the zoo: the full trainer pipeline
    // (sampler → weight mode → model-ops executor → optimizer) must be a
    // pure function of (model, seed) — rerunning any architecture yields
    // bit-identical loss sequences and traffic totals. For gcn/sage this
    // pins the ModelOps refactor to the pre-refactor behaviour (their ops
    // are verbatim transcriptions and the sampler oracle above pins the
    // batches); for gat/gin it pins the new end-to-end paths.
    for model in hitgnn::runtime::MODEL_NAMES {
        let a = run_losses(model, None);
        let b = run_losses(model, None);
        assert!(!a.0.is_empty(), "{model}: no iterations ran");
        assert!(a.0.iter().all(|l| l.is_finite()), "{model}: non-finite loss {:?}", a.0);
        assert_eq!(
            a.0.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            b.0.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            "{model}: loss sequence not reproducible"
        );
        assert_eq!(a.1, b.1, "{model}: traffic not reproducible");
    }
}
