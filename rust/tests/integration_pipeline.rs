//! Integration: the full coordinator pipeline — preprocess → sample →
//! schedule → dispatch to PJRT workers → gradient sync → SGD — on the
//! tiny dataset. Requires `make artifacts`.

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::partition::Algorithm;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        dataset: "tiny".into(),
        model: "gcn".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 2,
        epochs: 3,
        lr: 0.3,
        momentum: 0.9,
        scale_shift: 0,
        seed: 9,
        max_iterations: Some(12),
        ..TrainConfig::default()
    }
}

#[test]
fn training_loss_decreases_over_epochs() {
    let mut t = Trainer::new(base_cfg()).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.epochs.len(), 3);
    let first = report.epochs[0].mean_loss;
    let last = report.epochs[2].mean_loss;
    assert!(
        last < first * 0.9,
        "loss did not decrease: {first} -> {last}"
    );
    // metrics are populated
    let m = &report.epochs[0];
    assert!(m.batches > 0 && m.iterations > 0);
    assert!(m.vertices_traversed > 0);
    assert!(m.nvtps > 0.0);
    assert!(m.beta > 0.0 && m.beta <= 1.0);
    assert!(m.sample_seconds > 0.0 && m.execute_seconds > 0.0);
    // measured shapes within capacity: [v0, v1, v2, a1, a2] at L = 2
    assert_eq!(report.mean_shape.len(), 5);
    let (v0, v1, v2) = (report.mean_shape[0], report.mean_shape[1], report.mean_shape[2]);
    assert!(v2 > 0.0 && v1 >= v2 && v0 >= v1);
    assert!(report.mean_shape[3] > 0.0 && report.mean_shape[4] > 0.0);
    t.shutdown();
}

#[test]
fn all_three_algorithms_train() {
    for algo in Algorithm::ALL {
        let mut cfg = base_cfg();
        cfg.algo = algo;
        cfg.epochs = 1;
        cfg.max_iterations = Some(4);
        let mut t = Trainer::new(cfg).unwrap();
        let report = t.run().unwrap();
        assert!(report.last_loss().is_finite(), "{algo:?}");
        // P3 stores dim slices → beta ≈ 1/p; partition stores → nonzero
        let beta = report.epochs[0].beta;
        match algo {
            Algorithm::P3 => assert!((beta - 0.5).abs() < 0.1, "{algo:?} beta={beta}"),
            _ => assert!(beta > 0.2, "{algo:?} beta={beta}"),
        }
        t.shutdown();
    }
}

#[test]
fn sage_model_trains() {
    let mut cfg = base_cfg();
    cfg.model = "sage".into();
    cfg.epochs = 2;
    cfg.max_iterations = Some(8);
    let mut t = Trainer::new(cfg).unwrap();
    let report = t.run().unwrap();
    assert!(report.epochs[1].mean_loss < report.epochs[0].mean_loss * 1.05);
    t.shutdown();
}

#[test]
fn wb_and_dc_toggles_affect_accounting_not_correctness() {
    // With both optimizations off, training still converges; DC off must
    // produce f2f traffic for DistDGL (remote misses via shared memory).
    let mut cfg = base_cfg();
    cfg.workload_balancing = false;
    cfg.direct_host_fetch = false;
    cfg.epochs = 1;
    cfg.max_iterations = Some(6);
    let mut t = Trainer::new(cfg).unwrap();
    let report = t.run().unwrap();
    let m = &report.epochs[0];
    assert!(m.f2f_bytes > 0, "DC off must route misses via f2f");
    assert_eq!(m.host_bytes, 0, "DistDGL misses are all remote");
    assert!(report.last_loss().is_finite());
    t.shutdown();
}

#[test]
fn evaluate_reports_accuracy_above_chance() {
    // tiny has 8 classes; after a few epochs the planted-centroid labels
    // should be learnable well above 1/8
    let mut cfg = base_cfg();
    cfg.epochs = 4;
    cfg.max_iterations = Some(16);
    let mut t = Trainer::new(cfg).unwrap();
    let _ = t.run().unwrap();
    let acc = t.evaluate(4).unwrap();
    assert!(acc > 0.3, "accuracy {acc} not above chance");
    t.shutdown();
}

#[test]
fn prefetch_preserves_numerics() {
    // §8 extension: prefetching reorders host work only — the training
    // trajectory must be bit-identical
    let run = |prefetch: bool| {
        let mut cfg = base_cfg();
        cfg.prefetch = prefetch;
        cfg.epochs = 2;
        cfg.max_iterations = Some(6);
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run().unwrap();
        let losses: Vec<f64> = r.epochs.iter().map(|e| e.mean_loss).collect();
        t.shutdown();
        losses
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn deterministic_given_seed() {
    let run = || {
        let mut cfg = base_cfg();
        cfg.epochs = 1;
        cfg.max_iterations = Some(4);
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run().unwrap();
        let loss = r.epochs[0].mean_loss;
        t.shutdown();
        loss
    };
    let a = run();
    let b = run();
    assert!((a - b).abs() < 1e-9, "nondeterministic: {a} vs {b}");
}

#[test]
fn three_layer_fanouts_train_end_to_end() {
    // ISSUE 4 acceptance: a deeper-than-2 model trains end to end on the
    // reference executor (entry synthesized from --fanouts), for both
    // model families, and the loss goes down.
    for model in ["gcn", "sage"] {
        let mut cfg = base_cfg();
        cfg.model = model.into();
        cfg.fanouts = Some(vec![3, 2, 2]);
        cfg.epochs = 3;
        cfg.max_iterations = Some(8);
        let mut t = Trainer::new(cfg).unwrap();
        let report = t.run().unwrap();
        let first = report.epochs[0].mean_loss;
        let last = report.last_loss();
        assert!(last < first, "{model} L=3: loss did not decrease: {first} -> {last}");
        // the measured shape now carries 4 vertex levels + 3 edge layers
        assert_eq!(report.mean_shape.len(), 7);
        assert!(report.mean_shape[..4].windows(2).all(|w| w[0] >= w[1]));
        t.shutdown();
    }
}

#[test]
fn one_layer_fanouts_train_end_to_end() {
    let mut cfg = base_cfg();
    cfg.fanouts = Some(vec![4]);
    cfg.epochs = 2;
    cfg.max_iterations = Some(6);
    let mut t = Trainer::new(cfg).unwrap();
    let report = t.run().unwrap();
    assert!(report.last_loss().is_finite());
    assert_eq!(report.mean_shape.len(), 3);
    t.shutdown();
}
