//! Integration: artifacts → executor compile → execute, cross-checked
//! against host-side reference numerics. With the `pjrt` feature this
//! exercises the real AOT artifacts (requires `make artifacts`); the
//! default build runs the same checks against the built-in reference
//! executor via the synthetic manifest.

use hitgnn::comm::{CommConfig, FeatureService};
use hitgnn::coordinator::params::ParamSet;
use hitgnn::graph::datasets;
use hitgnn::partition::{preprocess, Algorithm};
use hitgnn::runtime::{BatchBuffers, Manifest, TrainExecutor};
use hitgnn::sampling::{Sampler, WeightMode};

fn manifest() -> Manifest {
    // real artifacts when built, builtin manifest (reference backend)
    // otherwise — the checks below hold for both executors
    Manifest::load_or_builtin(&Manifest::default_dir()).expect("manifest unavailable")
}

fn tiny_setup(
    model: &str,
) -> (
    hitgnn::graph::Dataset,
    hitgnn::partition::Preprocessed,
    hitgnn::sampling::MiniBatch,
    BatchBuffers,
    hitgnn::runtime::ArtifactEntry,
) {
    let m = manifest();
    let entry = m.find("train", model, "tiny").unwrap().clone();
    let data = datasets::lookup("tiny").unwrap().build(0, 7);
    let pre = preprocess(Algorithm::DistDgl, &data, 2, 0.2, 7);
    let mode = WeightMode::for_model(model).unwrap();
    let mut sampler = Sampler::new(
        entry.dims.fanout_config(),
        mode,
        data.graph.num_vertices(),
        11,
    );
    let targets: Vec<u32> = pre.train_parts[0][..entry.dims.b].to_vec();
    let mb = sampler.sample(&data, &targets, 0, 0);
    mb.validate().unwrap();
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let (feat0, _) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
    let batch = BatchBuffers::from_minibatch(&mb, feat0, entry.dims.f0());
    (data, pre, mb, batch, entry)
}

#[test]
fn train_step_executes_and_returns_finite_grads() {
    for model in ["gcn", "sage"] {
        let (_, _, _, batch, entry) = tiny_setup(model);
        let mut exe = TrainExecutor::compile(&entry).unwrap();
        let params = ParamSet::init(&entry, 3);
        let out = exe.train_step(&params.data, &batch).unwrap();
        assert!(out.loss.is_finite(), "{model}: loss {}", out.loss);
        assert!(out.loss > 0.0, "{model}: CE loss must be positive");
        assert_eq!(out.grads.len(), entry.params.len());
        for (g, (name, shape)) in out.grads.iter().zip(&entry.params) {
            assert_eq!(g.len(), shape.iter().product::<usize>(), "{model}/{name}");
            assert!(g.iter().all(|x| x.is_finite()), "{model}/{name} has non-finite grads");
        }
        // at least one gradient must be nonzero
        assert!(out.grads.iter().flatten().any(|&x| x != 0.0), "{model}: all-zero grads");
    }
}

#[test]
fn predict_logits_match_host_reference_for_gcn() {
    // full host-side recomputation of the 2-layer GCN forward (f32)
    let (_, _, mb, batch, entry) = tiny_setup("gcn");
    let m = manifest();
    let pentry = m.find("predict", "gcn", "tiny").unwrap().clone();
    let mut exe = TrainExecutor::compile(&pentry).unwrap();
    let params = ParamSet::init(&pentry, 3);
    let logits = exe.predict(&params.data, &batch).unwrap();

    let d = &entry.dims;
    let (f0, f1, f2) = (d.f[0], d.f[1], d.f[2]);
    let v1_cap = d.caps[1];
    let (w1, b1, w2, b2) = (&params.data[0], &params.data[1], &params.data[2], &params.data[3]);
    // layer 1: aggregate(feat0) -> update -> relu
    let agg1 = mb.aggregate_ref(1, &batch.feat0, f0); // [v1_cap, f0]
    let mut h1 = vec![0f32; v1_cap * f1];
    for r in 0..v1_cap {
        for j in 0..f1 {
            let mut acc = b1[j];
            for k in 0..f0 {
                acc += agg1[r * f0 + k] * w1[k * f1 + j];
            }
            h1[r * f1 + j] = acc.max(0.0);
        }
    }
    // layer 2: aggregate(h1 by idx[1]/w[1]) -> update
    let k2 = d.fanouts[1] + 1;
    let mut want = vec![0f32; d.b * f2];
    for r in 0..d.b {
        let mut agg = vec![0f32; f1];
        for c in 0..k2 {
            let w = batch.w[1][r * k2 + c];
            if w == 0.0 {
                continue;
            }
            let src = batch.idx[1][r * k2 + c] as usize;
            for j in 0..f1 {
                agg[j] += w * h1[src * f1 + j];
            }
        }
        for j in 0..f2 {
            let mut acc = b2[j];
            for k in 0..f1 {
                acc += agg[k] * w2[k * f2 + j];
            }
            want[r * f2 + j] = acc;
        }
    }
    assert_eq!(logits.len(), want.len());
    let mut max_err = 0f32;
    for (a, b) in logits.iter().zip(&want) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-3, "logits deviate from host reference: {max_err}");
}

#[test]
fn gradient_step_reduces_loss_through_pjrt() {
    let (_, _, _, batch, entry) = tiny_setup("gcn");
    let mut exe = TrainExecutor::compile(&entry).unwrap();
    let mut params = ParamSet::init(&entry, 5);
    let first = exe.train_step(&params.data, &batch).unwrap();
    let mut opt = hitgnn::coordinator::params::Sgd::new(0.5, 0.9, &params);
    let mut loss = first.loss;
    let mut grads = first.grads;
    for _ in 0..20 {
        opt.step(&mut params, &grads);
        let out = exe.train_step(&params.data, &batch).unwrap();
        loss = out.loss;
        grads = out.grads;
    }
    assert!(
        loss < first.loss * 0.8,
        "loss did not decrease through PJRT: {} -> {loss}",
        first.loss
    );
}

#[test]
fn executor_rejects_wrong_param_count_and_kind() {
    let (_, _, _, batch, entry) = tiny_setup("gcn");
    let mut exe = TrainExecutor::compile(&entry).unwrap();
    let params = ParamSet::init(&entry, 3);
    assert!(exe.train_step(&params.data[..2].to_vec(), &batch).is_err());
    assert!(exe.predict(&params.data, &batch).is_err()); // train artifact
}

#[test]
fn mask_zero_targets_do_not_affect_loss() {
    // two runs identical except for a masked-off target's label —
    // the masked loss must not change
    let (_, _, _, mut batch, entry) = tiny_setup("gcn");
    let mut exe = TrainExecutor::compile(&entry).unwrap();
    let params = ParamSet::init(&entry, 3);
    batch.mask[entry.dims.b - 1] = 0.0;
    let a = exe.train_step(&params.data, &batch).unwrap();
    batch.labels[entry.dims.b - 1] =
        (batch.labels[entry.dims.b - 1] + 1) % entry.dims.classes() as i32;
    let b = exe.train_step(&params.data, &batch).unwrap();
    assert!(
        (a.loss - b.loss).abs() < 1e-6,
        "masked target leaked into loss: {} vs {}",
        a.loss,
        b.loss
    );
}
