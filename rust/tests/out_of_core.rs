//! Out-of-core acceptance (DESIGN.md §Out-of-core storage): training from
//! a packed on-disk dataset must be bit-identical to the in-memory build
//! at a matched seed/config — the pack is a serialization of the same
//! deterministic generation, and the mmap-backed `Csr`/feature seams feed
//! the sampler and gather byte-for-byte the same data. The DRAM tier is
//! pure accounting above those seams, so it must never move the loss
//! sequence either; its hit/miss split has to partition the miss traffic
//! exactly.

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::graph::{datasets, ondisk};
use hitgnn::partition::Algorithm;
use hitgnn::store::CachePolicy;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        dataset: "tiny".into(),
        model: "gcn".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 2,
        epochs: 2,
        lr: 0.3,
        momentum: 0.9,
        scale_shift: 0,
        seed: 33,
        max_iterations: Some(6),
        ..TrainConfig::default()
    }
}

fn pack_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("hitgnn-ooc-tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.hitg", std::process::id()))
}

/// Per-iteration losses across epochs + the full report.
fn run(cfg: TrainConfig) -> (Vec<f64>, hitgnn::coordinator::TrainReport) {
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    t.shutdown();
    let losses: Vec<f64> = r.epochs.iter().flat_map(|e| e.iter_losses.iter().copied()).collect();
    (losses, r)
}

#[test]
fn packed_training_is_bit_identical_to_in_memory() {
    // pack with the generator seed the in-memory run will use — identity
    // of the loss sequence is exact, not approximate
    let spec = datasets::lookup("tiny").unwrap();
    let path = pack_path("train-roundtrip");
    ondisk::pack_streamed(&spec, 0, 33, &path, 1 << 20).unwrap();

    let (mem_losses, mem_report) = run(base_cfg());
    assert!(!mem_losses.is_empty() && mem_losses.iter().all(|l| l.is_finite()));

    let mut cfg = base_cfg();
    // deliberately wrong key: the pack's embedded identity must win
    cfg.dataset = "reddit".into();
    cfg.scale_shift = 9;
    cfg.dataset_path = Some(path.to_str().unwrap().to_string());
    let (packed_losses, packed_report) = run(cfg);

    assert_eq!(mem_losses, packed_losses, "mmap-backed training diverged from in-memory");
    assert_eq!(packed_report.config.req_str("dataset").unwrap(), "tiny");
    assert_eq!(packed_report.config.req_usize("scale_shift").unwrap(), 0);
    for (a, b) in mem_report.epochs.iter().zip(packed_report.epochs.iter()) {
        assert_eq!(a.local_bytes, b.local_bytes, "epoch {}: traffic diverged", a.epoch);
        assert_eq!(a.host_bytes, b.host_bytes, "epoch {}: traffic diverged", a.epoch);
        assert_eq!(a.f2f_bytes, b.f2f_bytes, "epoch {}: traffic diverged", a.epoch);
        assert_eq!(a.dedup_saved_bytes, b.dedup_saved_bytes, "epoch {}", a.epoch);
        assert_eq!(a.batches, b.batches);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn packed_dataset_loads_through_the_mmap_path() {
    let spec = datasets::lookup("tiny").unwrap();
    let path = pack_path("mmap-seams");
    let in_mem = spec.build(1, 42);
    ondisk::pack_streamed(&spec, 1, 42, &path, 1 << 20).unwrap();
    let data = ondisk::load(&path).unwrap();
    // on little-endian 64-bit hosts the CSR and feature rows are served
    // zero-copy from the mapping; elsewhere the owned-decode fallback
    // must be in effect — either way the data is identical
    assert_eq!(data.graph.is_mapped(), ondisk::zero_copy_ok());
    assert_eq!(data.features.is_mapped(), ondisk::zero_copy_ok());
    assert_eq!(data.graph.num_vertices(), in_mem.graph.num_vertices());
    assert_eq!(data.graph.num_edges(), in_mem.graph.num_edges());
    assert_eq!(data.train_vertices, in_mem.train_vertices);
    for v in [0u32, 7, 1000, data.graph.num_vertices() as u32 - 1] {
        assert_eq!(data.graph.neighbors(v), in_mem.graph.neighbors(v), "vertex {v}");
        let f0 = data.features.feat_dim();
        let (mut a, mut b) = (vec![0f32; f0], vec![0f32; f0]);
        data.features.write_features(v, &mut a);
        in_mem.features.write_features(v, &mut b);
        assert_eq!(a, b, "features diverged at vertex {v}");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn dram_tier_preserves_losses_and_partitions_miss_traffic() {
    let (base_losses, base_report) = run(base_cfg());
    assert!(!base_losses.is_empty());
    for policy in [CachePolicy::Static, CachePolicy::Lfu, CachePolicy::Window] {
        let mut cfg = base_cfg();
        cfg.cache_policy = policy;
        cfg.dram_ratio = 0.3;
        cfg.disk_gbs = 2.0;
        let (losses, report) = run(cfg);
        // the tier is accounting above the gather seam: no numeric drift
        if policy == CachePolicy::Static {
            assert_eq!(base_losses, losses, "DRAM tier moved the loss sequence");
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        for m in &report.epochs {
            let missed = m.host_bytes + m.f2f_bytes + m.dedup_saved_bytes;
            assert_eq!(
                m.dram_hit_bytes + m.disk_read_bytes,
                missed,
                "{policy:?} epoch {}: tier split must partition miss bytes",
                m.epoch
            );
        }
        let disk: u64 = report.epochs.iter().map(|m| m.disk_read_bytes).sum();
        assert!(disk > 0, "{policy:?}: a 0.3 tier must miss to disk");
        // dynamic tiers re-rank at the barrier (counted with the stores)
        if policy.is_dynamic() {
            assert!(report.epochs[0].stores_updated > 0, "{policy:?}: tier never re-ranked");
        }
    }
    // without a tier the split fields stay zero
    for m in &base_report.epochs {
        assert_eq!((m.dram_hit_bytes, m.disk_read_bytes), (0, 0));
    }
}

#[test]
fn packed_tiered_run_matches_in_memory_tiered_run() {
    // the full out-of-core stack: mmap pack + DRAM tier, vs the in-memory
    // build with the same tier — bit-identical losses and tier split
    let spec = datasets::lookup("tiny").unwrap();
    let path = pack_path("tiered");
    ondisk::pack_streamed(&spec, 0, 33, &path, 1 << 20).unwrap();
    let tier_cfg = || {
        let mut c = base_cfg();
        c.cache_policy = CachePolicy::Lfu;
        c.dram_ratio = 0.25;
        c
    };
    let (mem_losses, mem_report) = run(tier_cfg());
    let mut cfg = tier_cfg();
    cfg.dataset_path = Some(path.to_str().unwrap().to_string());
    let (packed_losses, packed_report) = run(cfg);
    assert_eq!(mem_losses, packed_losses);
    for (a, b) in mem_report.epochs.iter().zip(packed_report.epochs.iter()) {
        assert_eq!(a.dram_hit_bytes, b.dram_hit_bytes, "epoch {}", a.epoch);
        assert_eq!(a.disk_read_bytes, b.disk_read_bytes, "epoch {}", a.epoch);
    }
    std::fs::remove_file(&path).ok();
}
