//! Pipeline determinism: a fixed seed must produce a bit-identical
//! per-iteration loss sequence and identical Traffic totals for every
//! `host-threads` × `prefetch-depth` combination — including the serial
//! path (1, 1) the seed implemented. Also pins down that `max_iterations`
//! caps *prepared* work, not just executed work (no prepared-but-never-
//! executed batches may leak into the metrics).

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::fpga::parse_fleet;
use hitgnn::partition::Algorithm;
use hitgnn::sched::SchedMode;
use hitgnn::store::CachePolicy;
use hitgnn::tune::AutoTuneMode;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        dataset: "tiny".into(),
        model: "gcn".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 2,
        epochs: 2,
        lr: 0.3,
        momentum: 0.9,
        scale_shift: 0,
        seed: 33,
        max_iterations: Some(6),
        ..TrainConfig::default()
    }
}

/// (per-iteration losses across epochs, traffic totals incl. dedup,
/// batches, iters).
fn run_cfg(
    mut cfg: TrainConfig,
    host_threads: usize,
    prefetch_depth: usize,
) -> (Vec<f64>, (u64, u64, u64, u64), usize, usize) {
    cfg.host_threads = host_threads;
    cfg.prefetch_depth = prefetch_depth;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    let losses: Vec<f64> = r.epochs.iter().flat_map(|e| e.iter_losses.iter().copied()).collect();
    let traffic = r.epochs.iter().fold((0u64, 0u64, 0u64, 0u64), |acc, e| {
        (
            acc.0 + e.local_bytes,
            acc.1 + e.host_bytes,
            acc.2 + e.f2f_bytes,
            acc.3 + e.dedup_saved_bytes,
        )
    });
    let batches: usize = r.epochs.iter().map(|e| e.batches).sum();
    let iters: usize = r.epochs.iter().map(|e| e.iterations).sum();
    t.shutdown();
    (losses, traffic, batches, iters)
}

fn run(host_threads: usize, prefetch_depth: usize) -> (Vec<f64>, (u64, u64, u64, u64), usize, usize) {
    run_cfg(base_cfg(), host_threads, prefetch_depth)
}

#[test]
fn loss_sequence_invariant_across_pipeline_configs() {
    let base = run(1, 1);
    assert!(!base.0.is_empty(), "no iterations recorded");
    assert!(base.0.iter().all(|l| l.is_finite()));
    for (ht, d) in [(1, 3), (4, 1), (4, 3)] {
        let got = run(ht, d);
        assert_eq!(
            base.0, got.0,
            "loss sequence diverged at host-threads={ht} prefetch-depth={d}"
        );
        assert_eq!(base.1, got.1, "traffic diverged at ({ht}, {d})");
        assert_eq!(base.2, got.2, "batch count diverged at ({ht}, {d})");
        assert_eq!(base.3, got.3, "iteration count diverged at ({ht}, {d})");
    }
}

#[test]
fn loss_sequence_invariant_across_pipeline_configs_for_every_model() {
    // ISSUE 8 acceptance: the determinism law is a property of the
    // pipeline, not of one architecture — every model-zoo entry (the GAT
    // attention path and GIN MLP path included) must produce bit-identical
    // loss sequences and Traffic totals across host-threads ×
    // prefetch-depth. gcn's full grid is covered above; here each model
    // runs the serial path against the most concurrent one.
    for model in hitgnn::runtime::MODEL_NAMES {
        let cfg = || {
            let mut c = base_cfg();
            c.model = model.into();
            c
        };
        let base = run_cfg(cfg(), 1, 1);
        assert!(!base.0.is_empty(), "{model}: no iterations recorded");
        assert!(base.0.iter().all(|l| l.is_finite()), "{model}: non-finite loss");
        for (ht, d) in [(4, 1), (4, 3)] {
            let got = run_cfg(cfg(), ht, d);
            assert_eq!(
                base.0, got.0,
                "{model}: loss sequence diverged at host-threads={ht} prefetch-depth={d}"
            );
            assert_eq!(base.1, got.1, "{model}: traffic diverged at ({ht}, {d})");
            assert_eq!(base.2, got.2, "{model}: batch count diverged at ({ht}, {d})");
            assert_eq!(base.3, got.3, "{model}: iteration count diverged at ({ht}, {d})");
        }
    }
}

#[test]
fn dynamic_policy_runs_stay_bit_identical_across_pipeline_configs() {
    // ISSUE 2 acceptance: dynamic feature-store policies (epoch-snapshot
    // reads, barrier-ordered observe, epoch-barrier re-rank) plus the
    // iteration-level fetch dedup must preserve the determinism law.
    for policy in [CachePolicy::Lfu, CachePolicy::Window] {
        let cfg = || {
            let mut c = base_cfg();
            c.cache_policy = policy;
            c.cache_ratio = 0.15;
            c
        };
        let base = run_cfg(cfg(), 1, 1);
        assert!(!base.0.is_empty(), "no iterations recorded");
        assert!(base.0.iter().all(|l| l.is_finite()));
        for (ht, d) in [(1, 3), (4, 1), (4, 3)] {
            let got = run_cfg(cfg(), ht, d);
            assert_eq!(
                base.0, got.0,
                "{policy:?}: loss sequence diverged at host-threads={ht} prefetch-depth={d}"
            );
            assert_eq!(base.1, got.1, "{policy:?}: traffic diverged at ({ht}, {d})");
            assert_eq!(base.2, got.2, "{policy:?}: batch count diverged at ({ht}, {d})");
            assert_eq!(base.3, got.3, "{policy:?}: iteration count diverged at ({ht}, {d})");
        }
    }
}

#[test]
fn fetch_dedup_only_moves_host_bytes_and_defaults_on() {
    // PaGraph: every FPGA shares the same degree-ranked cache, so the
    // per-FPGA batches of one iteration miss on the same hot vertices —
    // the canonical case iteration-level dedup exists for. (DistDGL at
    // p=2 has provably disjoint miss sets: each FPGA only misses the
    // other partition's rows.)
    let cfg = || {
        let mut c = base_cfg();
        c.algo = Algorithm::PaGraph;
        c.cache_ratio = 0.15;
        c
    };
    let mut no_dedup = cfg();
    no_dedup.fetch_dedup = false;
    let off = run_cfg(no_dedup, 4, 2);
    let on = run_cfg(cfg(), 4, 2);
    // identical work either way
    assert_eq!(off.0, on.0, "dedup must not touch the numerics");
    assert_eq!(off.2, on.2);
    let (l_off, h_off, f_off, s_off) = off.1;
    let (l_on, h_on, f_on, s_on) = on.1;
    assert_eq!(s_off, 0, "--no-dedup records no savings");
    assert_eq!(l_off, l_on);
    assert_eq!(f_off, f_on);
    // conservation: dedup reclassifies host bytes, byte-for-byte
    assert_eq!(h_off, h_on + s_on);
    assert!(s_on > 0, "expected iteration-level dedup savings");
}

#[test]
fn determinism_holds_across_sched_modes_on_heterogeneous_fleet() {
    // ISSUE 3 acceptance: the determinism law (bit-identical loss and
    // Traffic across pipeline configurations) must hold in *both*
    // scheduler modes on a heterogeneous fleet. Full epochs (no cap) so
    // the stage-2 tail — where the modes actually assign differently —
    // is exercised.
    let cfg_for = |mode: SchedMode| {
        let mut c = base_cfg();
        c.fleet = Some(parse_fleet("u250-half:1,u250:1").unwrap());
        c.sched = mode;
        // one full (uncapped) epoch reaches the end-of-epoch tail
        c.epochs = 1;
        c.max_iterations = None;
        c
    };
    let mut per_mode = Vec::new();
    for mode in SchedMode::ALL {
        let base = run_cfg(cfg_for(mode), 1, 1);
        assert!(!base.0.is_empty(), "no iterations recorded");
        assert!(base.0.iter().all(|l| l.is_finite()));
        for (ht, d) in [(4, 1), (4, 3)] {
            let got = run_cfg(cfg_for(mode), ht, d);
            assert_eq!(
                base.0, got.0,
                "{mode:?}: loss sequence diverged at host-threads={ht} prefetch-depth={d}"
            );
            assert_eq!(base.1, got.1, "{mode:?}: traffic diverged at ({ht}, {d})");
            assert_eq!(base.2, got.2, "{mode:?}: batch count diverged at ({ht}, {d})");
            assert_eq!(base.3, got.3, "{mode:?}: iteration count diverged at ({ht}, {d})");
        }
        per_mode.push(base);
    }
    // the modes are paired ablations: identical (part, seq) consumption
    // per iteration means a bit-identical loss sequence and identical
    // batch/iteration counts — only the device assignment (and therefore
    // the Traffic split) may move between them
    assert_eq!(
        per_mode[0].0, per_mode[1].0,
        "batch-count and cost modes must produce bit-identical losses"
    );
    assert_eq!(per_mode[0].2, per_mode[1].2);
    assert_eq!(per_mode[0].3, per_mode[1].3);
}

#[test]
fn determinism_holds_at_depth_three_across_pipeline_and_sched() {
    // ISSUE 4 acceptance: the determinism law must hold at L = 3 — a
    // fanout override changes the wire format everywhere (sampler,
    // gather, executor), none of which may depend on pipeline config or
    // scheduler mode. Heterogeneous fleet so the modes actually differ.
    let cfg_for = |mode: SchedMode| {
        let mut c = base_cfg();
        c.fanouts = Some(vec![3, 2, 2]);
        c.fleet = Some(parse_fleet("u250-half:1,u250:1").unwrap());
        c.sched = mode;
        c
    };
    let mut per_mode = Vec::new();
    for mode in SchedMode::ALL {
        let base = run_cfg(cfg_for(mode), 1, 1);
        assert!(!base.0.is_empty(), "no iterations recorded");
        assert!(base.0.iter().all(|l| l.is_finite()));
        for (ht, d) in [(1, 3), (4, 1), (4, 3)] {
            let got = run_cfg(cfg_for(mode), ht, d);
            assert_eq!(
                base.0, got.0,
                "{mode:?} L=3: loss sequence diverged at host-threads={ht} prefetch-depth={d}"
            );
            assert_eq!(base.1, got.1, "{mode:?} L=3: traffic diverged at ({ht}, {d})");
            assert_eq!(base.2, got.2, "{mode:?} L=3: batch count diverged at ({ht}, {d})");
            assert_eq!(base.3, got.3, "{mode:?} L=3: iteration count diverged at ({ht}, {d})");
        }
        per_mode.push(base);
    }
    // scheduler modes stay paired ablations at depth 3
    assert_eq!(per_mode[0].0, per_mode[1].0, "sched modes must pair bit-identically at L=3");
    assert_eq!(per_mode[0].2, per_mode[1].2);
    assert_eq!(per_mode[0].3, per_mode[1].3);
}

#[test]
fn auto_tuner_preserves_loss_sequence_at_depth_two_and_three() {
    // ISSUE 6 acceptance: the closed-loop controller only moves
    // loss-invariant knobs (host-threads, prefetch-depth, sched,
    // cache-ratio) at epoch boundaries, so `--auto-tune on` must produce
    // a bit-identical per-iteration loss sequence to `freeze` (observe /
    // log, never retune) and `off`. Heterogeneous fleet and enough
    // epochs that the controller actually takes steps; traffic may move
    // (sched flips re-split bytes across devices) but work may not.
    for fanouts in [None, Some(vec![3usize, 2, 2])] {
        let cfg_for = |mode: AutoTuneMode| {
            let mut c = base_cfg();
            c.fanouts = fanouts.clone();
            c.fleet = Some(parse_fleet("u250-half:1,u250:1").unwrap());
            c.epochs = 5;
            c.auto_tune = mode;
            c
        };
        let run_mode = |mode: AutoTuneMode| {
            let mut t = Trainer::new(cfg_for(mode)).unwrap();
            let r = t.run().unwrap();
            t.shutdown();
            r
        };
        let frozen = run_mode(AutoTuneMode::Freeze);
        let tuned = run_mode(AutoTuneMode::On);
        let off = run_mode(AutoTuneMode::Off);
        let losses = |r: &hitgnn::coordinator::TrainReport| -> Vec<f64> {
            r.epochs.iter().flat_map(|e| e.iter_losses.iter().copied()).collect()
        };
        let base = losses(&frozen);
        assert!(!base.is_empty(), "no iterations recorded");
        assert!(base.iter().all(|l| l.is_finite()));
        assert_eq!(base, losses(&tuned), "fanouts={fanouts:?}: auto-tune on diverged from freeze");
        assert_eq!(base, losses(&off), "fanouts={fanouts:?}: freeze diverged from off");
        for (a, b) in frozen.epochs.iter().zip(tuned.epochs.iter()) {
            assert_eq!(a.batches, b.batches, "fanouts={fanouts:?}: batch count moved");
            assert_eq!(a.iterations, b.iterations, "fanouts={fanouts:?}: iteration count moved");
        }
        // both controller modes log a decision every epoch; off logs none
        assert!(tuned.epochs.iter().all(|e| e.tune.is_some()));
        assert!(frozen.epochs.iter().all(|e| e.tune.is_some()));
        assert!(off.epochs.iter().all(|e| e.tune.is_none()));
    }
}

#[test]
fn buffer_pool_recycling_is_observationally_invisible() {
    // ISSUE 5 acceptance: recycled batch buffers (sampler carcasses,
    // gather buffers, executor input buffers) may never leak state
    // between batches. Per-iteration losses and Traffic totals must be
    // bit-identical with the pool on and off (--no-pool), at L = 2 (the
    // tiny artifact's [3, 2]) and L = 3, across host-threads ×
    // prefetch-depth — so buffer reuse is observationally invisible.
    for fanouts in [None, Some(vec![3usize, 2, 2])] {
        let cfg_for = |pool: bool| {
            let mut c = base_cfg();
            c.fanouts = fanouts.clone();
            c.buffer_pool = pool;
            c
        };
        let base = run_cfg(cfg_for(true), 1, 1);
        assert!(!base.0.is_empty(), "no iterations recorded");
        assert!(base.0.iter().all(|l| l.is_finite()));
        let cases = [(false, 1, 1), (true, 4, 2), (false, 4, 2), (true, 2, 3), (false, 2, 3)];
        for (pool, ht, d) in cases {
            let got = run_cfg(cfg_for(pool), ht, d);
            assert_eq!(
                base.0, got.0,
                "fanouts={fanouts:?} pool={pool}: losses diverged at ({ht}, {d})"
            );
            assert_eq!(
                base.1, got.1,
                "fanouts={fanouts:?} pool={pool}: traffic diverged at ({ht}, {d})"
            );
            assert_eq!(base.2, got.2, "fanouts={fanouts:?} pool={pool}: batches at ({ht}, {d})");
            assert_eq!(base.3, got.3, "fanouts={fanouts:?} pool={pool}: iters at ({ht}, {d})");
        }
    }
}

#[test]
fn reduce_thread_count_never_changes_the_loss_sequence() {
    // ISSUE 7 acceptance: the parallel gradient reduction keeps every
    // per-element sum in worker tag order (g0 + g1 + ... + g_{p-1}), so
    // the reduction-thread count is a pure throughput knob — losses,
    // traffic, and work must stay bit-identical across it. Force the
    // scoped-thread path even on tiny's small parameter set (which sits
    // far below PAR_MIN_ELEMS and would otherwise reduce serially); the
    // override only moves the serial cutoff, which by the same law is
    // invisible to every other test in this binary.
    std::env::set_var("HITGNN_REDUCE_PAR_MIN", "1");
    let cfg_for = |rt: usize| {
        let mut c = base_cfg();
        c.reduce_threads = rt;
        c
    };
    let base = run_cfg(cfg_for(1), 1, 1);
    assert!(!base.0.is_empty(), "no iterations recorded");
    assert!(base.0.iter().all(|l| l.is_finite()));
    for rt in [2, 4] {
        for (ht, d) in [(1, 1), (4, 2)] {
            let got = run_cfg(cfg_for(rt), ht, d);
            assert_eq!(
                base.0, got.0,
                "loss sequence diverged at reduce-threads={rt} host-threads={ht} depth={d}"
            );
            assert_eq!(base.1, got.1, "traffic diverged at reduce-threads={rt} ({ht}, {d})");
            assert_eq!(base.2, got.2, "batch count diverged at reduce-threads={rt} ({ht}, {d})");
            assert_eq!(base.3, got.3, "iteration count diverged at reduce-threads={rt} ({ht}, {d})");
        }
    }
}

#[test]
fn determinism_law_survives_an_injected_fault_plan() {
    // ISSUE 10 acceptance: the determinism law must hold *under faults*.
    // A plan combining a device loss, a straggler, and transient disk
    // errors — keyed on logical (epoch, iter) positions and a stateless
    // eio hash, never wall-clock — must produce bit-identical losses and
    // Traffic across host-threads × prefetch-depth × sched on a
    // heterogeneous fleet.
    let cfg_for = |mode: SchedMode| {
        let mut c = base_cfg();
        c.fleet = Some(parse_fleet("u250-half:1,u250:1").unwrap());
        c.sched = mode;
        c.epochs = 2;
        c.max_iterations = None; // quarantine reroutes land in the tail
        c.fault_plan = Some(
            hitgnn::fault::FaultPlan::parse("dev1:fail@e1i2,dev0:slow*3@e0,disk:eio@0.2")
                .unwrap(),
        );
        c
    };
    for mode in SchedMode::ALL {
        let base = run_cfg(cfg_for(mode), 1, 1);
        assert!(!base.0.is_empty(), "no iterations recorded");
        assert!(base.0.iter().all(|l| l.is_finite()));
        for (ht, d) in [(1, 3), (4, 1), (4, 3)] {
            let got = run_cfg(cfg_for(mode), ht, d);
            assert_eq!(
                base.0, got.0,
                "{mode:?} faulted: loss sequence diverged at host-threads={ht} prefetch-depth={d}"
            );
            assert_eq!(base.1, got.1, "{mode:?} faulted: traffic diverged at ({ht}, {d})");
            assert_eq!(base.2, got.2, "{mode:?} faulted: batch count diverged at ({ht}, {d})");
            assert_eq!(base.3, got.3, "{mode:?} faulted: iteration count diverged at ({ht}, {d})");
        }
    }
}

#[test]
fn training_resumes_bit_identically_from_a_checkpoint() {
    // ISSUE 10 acceptance (continuation law): training N epochs straight
    // must equal training N/2, checkpointing, and resuming for the rest —
    // bit-identical per-iteration losses and Traffic totals for the
    // resumed half. Dynamic cache policy + DRAM tier on a heterogeneous
    // fleet, so every piece of state the snapshot carries (params,
    // momentum, RNG, store residency, tier) is actually load-bearing.
    // (Tuner-state roundtrip is covered separately below: the controller
    // keys on measured wall clock, so its knob choices — and therefore
    // traffic splits — are not byte-reproducible across runs.)
    let dir = std::env::temp_dir()
        .join(format!("hitgnn_resume_equiv_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = |epochs: usize| {
        let mut c = base_cfg();
        c.fleet = Some(parse_fleet("u250-half:1,u250:1").unwrap());
        c.cache_policy = CachePolicy::Lfu;
        c.cache_ratio = 0.15;
        c.dram_ratio = 0.5;
        c.epochs = epochs;
        c
    };
    let run = |c: TrainConfig| {
        let mut t = Trainer::new(c).unwrap();
        let r = t.run().unwrap();
        t.shutdown();
        r
    };
    // straight run: 6 epochs, no checkpointing
    let straight = run(cfg(6));
    // halved run: 3 epochs with snapshots, then resume for the rest
    let mut first = cfg(3);
    first.checkpoint_dir = Some(dir.clone());
    let head = run(first);
    assert!(head.epochs.iter().all(|e| e.checkpoint_seconds > 0.0));
    let mut second = cfg(6);
    second.resume = Some(dir.display().to_string());
    let tail = run(second);
    // the resumed run reports exactly the remaining epochs
    assert_eq!(tail.epochs.len(), 3);
    assert_eq!(tail.epochs[0].epoch, 3);
    for (a, b) in straight.epochs[3..].iter().zip(&tail.epochs) {
        assert_eq!(a.epoch, b.epoch);
        assert_eq!(
            a.iter_losses, b.iter_losses,
            "epoch {}: resumed losses diverged from the straight run",
            a.epoch
        );
        assert_eq!(a.batches, b.batches, "epoch {}", a.epoch);
        assert_eq!(a.iterations, b.iterations, "epoch {}", a.epoch);
        assert_eq!(a.local_bytes, b.local_bytes, "epoch {}", a.epoch);
        assert_eq!(a.host_bytes, b.host_bytes, "epoch {}", a.epoch);
        assert_eq!(a.f2f_bytes, b.f2f_bytes, "epoch {}", a.epoch);
        assert_eq!(a.dedup_saved_bytes, b.dedup_saved_bytes, "epoch {}", a.epoch);
        assert_eq!(a.dram_hit_bytes, b.dram_hit_bytes, "epoch {}", a.epoch);
        assert_eq!(a.disk_read_bytes, b.disk_read_bytes, "epoch {}", a.epoch);
    }
    // and the head half matches the straight run too (checkpointing is
    // observationally invisible to the numerics)
    for (a, b) in straight.epochs[..3].iter().zip(&head.epochs) {
        assert_eq!(a.iter_losses, b.iter_losses, "epoch {}: checkpointing moved a loss", a.epoch);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_preserves_the_loss_sequence_with_the_auto_tuner_on() {
    // the tuner's decisions key on measured wall clock, so a resumed
    // controller may pick different knobs than the straight run — but
    // every knob it can move is loss-invariant, so the continuation law
    // still holds for the numerics. The snapshot carries the controller
    // state (validated: resuming without `--auto-tune` is an error), and
    // the resumed half keeps logging decisions.
    let dir = std::env::temp_dir()
        .join(format!("hitgnn_resume_tune_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = |epochs: usize| {
        let mut c = base_cfg();
        c.fleet = Some(parse_fleet("u250-half:1,u250:1").unwrap());
        c.auto_tune = AutoTuneMode::On;
        c.epochs = epochs;
        c
    };
    let run = |c: TrainConfig| {
        let mut t = Trainer::new(c).unwrap();
        let r = t.run().unwrap();
        t.shutdown();
        r
    };
    let straight = run(cfg(6));
    let mut first = cfg(3);
    first.checkpoint_dir = Some(dir.clone());
    run(first);
    // a tuner-carrying checkpoint refuses to resume into --auto-tune off
    let mut off = cfg(6);
    off.auto_tune = AutoTuneMode::Off;
    off.resume = Some(dir.display().to_string());
    let err = Trainer::new(off).unwrap_err().to_string();
    assert!(err.contains("auto-tune"), "{err}");
    let mut second = cfg(6);
    second.resume = Some(dir.display().to_string());
    let tail = run(second);
    assert_eq!(tail.epochs.len(), 3);
    for (a, b) in straight.epochs[3..].iter().zip(&tail.epochs) {
        assert_eq!(
            a.iter_losses, b.iter_losses,
            "epoch {}: tuned resume moved the loss sequence",
            a.epoch
        );
        assert_eq!(a.batches, b.batches, "epoch {}", a.epoch);
        assert!(b.tune.is_some(), "epoch {}: restored controller logs decisions", a.epoch);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_equivalence_holds_under_a_fault_plan() {
    // continuation law × fault injection: a device lost in the first half
    // stays quarantined across resume (the mask rides in the snapshot),
    // and disk-eio draws — keyed on absolute (epoch, iter) — line up.
    let dir = std::env::temp_dir()
        .join(format!("hitgnn_resume_fault_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = |epochs: usize| {
        let mut c = base_cfg();
        c.epochs = epochs;
        c.max_iterations = None;
        c.fault_plan =
            Some(hitgnn::fault::FaultPlan::parse("dev0:fail@e1i1,disk:eio@0.2").unwrap());
        c
    };
    let run = |c: TrainConfig| {
        let mut t = Trainer::new(c).unwrap();
        let r = t.run().unwrap();
        t.shutdown();
        r
    };
    let straight = run(cfg(4));
    let mut first = cfg(2);
    first.checkpoint_dir = Some(dir.clone());
    run(first);
    let mut second = cfg(4);
    second.resume = Some(dir.display().to_string());
    let tail = run(second);
    for (a, b) in straight.epochs[2..].iter().zip(&tail.epochs) {
        assert_eq!(a.iter_losses, b.iter_losses, "epoch {}: faulted resume diverged", a.epoch);
        assert_eq!(a.quarantined_devices, b.quarantined_devices, "epoch {}", a.epoch);
        assert_eq!(a.reassigned_batches, b.reassigned_batches, "epoch {}", a.epoch);
        assert_eq!(a.disk_retries, b.disk_retries, "epoch {}", a.epoch);
        assert_eq!(a.batches, b.batches, "epoch {}", a.epoch);
    }
    assert!(tail.epochs.iter().all(|e| e.quarantined_devices == 1), "quarantine must persist");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn legacy_prefetch_flag_equals_depth_two() {
    let mut cfg_flag = base_cfg();
    cfg_flag.prefetch = true;
    let mut cfg_depth = base_cfg();
    cfg_depth.prefetch_depth = 2;

    let losses = |cfg: TrainConfig| {
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run().unwrap();
        let l: Vec<f64> =
            r.epochs.iter().flat_map(|e| e.iter_losses.iter().copied()).collect();
        t.shutdown();
        l
    };
    assert_eq!(losses(cfg_flag), losses(cfg_depth));
}

#[test]
fn max_iterations_bounds_prepared_batches() {
    // tiny / DistDGL p=2: both partitions hold well over 3 batches, so the
    // first 3 iterations are stage-1 (exactly one batch per FPGA). A cap
    // of 3 must therefore prepare and count exactly 6 batches — a
    // prepared-but-never-executed extra iteration would show up here.
    let mut cfg = base_cfg();
    cfg.epochs = 1;
    cfg.max_iterations = Some(3);
    cfg.host_threads = 4;
    cfg.prefetch_depth = 3; // deep window: over-preparation would be easy
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    let m = &r.epochs[0];
    assert_eq!(m.iterations, 3);
    assert_eq!(m.batches, 6, "prepared batches must match executed iterations");
    assert_eq!(m.iter_losses.len(), 3);
    t.shutdown();
}

#[test]
fn pipelined_trainer_still_evaluates() {
    let mut cfg = base_cfg();
    cfg.host_threads = 4;
    cfg.prefetch_depth = 2;
    cfg.epochs = 3;
    cfg.max_iterations = Some(12);
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    assert!(r.last_loss().is_finite());
    let acc = t.evaluate(4).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    t.shutdown();
}
