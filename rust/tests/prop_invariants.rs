//! Property-based invariants over the L3 substrates, driven by the
//! in-repo `util::proptest` helper (seed-reproducible random cases).

use hitgnn::fpga::timing::{BatchShape, ModelCost, TimingModel};
use hitgnn::fpga::{DieConfig, ResourceModel, U250};
use hitgnn::graph::datasets;
use hitgnn::partition::{preprocess, preprocess_with_policy, Algorithm};
use hitgnn::perf::{PlatformModel, PlatformSpec, Workload};
use hitgnn::sampling::{FanoutConfig, Sampler, WeightMode};
use hitgnn::sched::{epoch_makespan_seconds, CostModel, SchedMode, TwoStageScheduler};
use hitgnn::store::{dynamic::degree_rank, CachePolicy, FeatureStore, TieredStore};
use hitgnn::util::json::Json;
use hitgnn::util::proptest::{check, require};
use hitgnn::util::rng::Rng;

// ---------------------------------------------------------------------
// scheduler (Algorithm 3)
// ---------------------------------------------------------------------

#[test]
fn scheduler_executes_every_batch_exactly_once() {
    check("sched exactly-once", 128, |rng| {
        let p = 1 + rng.index(8);
        let counts: Vec<usize> = (0..p).map(|_| rng.index(40)).collect();
        if counts.iter().sum::<usize>() == 0 {
            return Ok(());
        }
        let wb = rng.bool(0.5);
        // cover both assignment modes: batch-count and cost-aware over a
        // random heterogeneous fleet
        let mut sched = if rng.bool(0.5) {
            TwoStageScheduler::new(p, wb)
        } else {
            let batch_s: Vec<f64> = (0..p).map(|_| 0.5 + rng.f64() * 4.0).collect();
            TwoStageScheduler::with_cost(p, wb, CostModel::new(batch_s))
        };
        let plans = sched.plan_epoch(&counts);
        let mut consumed = vec![0usize; p];
        for plan in &plans {
            require(plan.tasks.len() <= p, "iteration wider than p")?;
            for t in &plan.tasks {
                require(t.fpga < p && t.part < p, "task indices in range")?;
                consumed[t.part] += 1;
            }
        }
        require(consumed == counts, &format!("{consumed:?} != {counts:?}"))
    });
}

#[test]
fn cost_aware_makespan_seconds_never_worse_than_batch_count() {
    check("cost dominance", 96, |rng| {
        let p = 2 + rng.index(6);
        let counts: Vec<usize> = (0..p).map(|_| rng.index(30)).collect();
        if counts.iter().sum::<usize>() == 0 {
            return Ok(());
        }
        // random heterogeneous fleet: per-device batch seconds in [0.5, 4.5)
        let batch_s: Vec<f64> = (0..p).map(|_| 0.5 + rng.f64() * 4.0).collect();
        let cost = CostModel::new(batch_s);
        let mut bc = TwoStageScheduler::new(p, true);
        let mut ca = TwoStageScheduler::with_cost(p, true, cost.clone());
        let plans_bc = bc.plan_epoch(&counts);
        let plans_ca = ca.plan_epoch(&counts);
        let m_bc = epoch_makespan_seconds(&plans_bc, &cost);
        let m_ca = epoch_makespan_seconds(&plans_ca, &cost);
        require(
            m_ca <= m_bc + 1e-9,
            &format!("cost {m_ca} worse than batch-count {m_bc} for {counts:?}"),
        )?;
        // the two modes are paired: same iteration count and the same
        // partition multiset per iteration (only device assignment moves)
        require(plans_bc.len() == plans_ca.len(), "iteration structure diverged")?;
        for (a, b) in plans_bc.iter().zip(&plans_ca) {
            let parts = |pl: &hitgnn::sched::IterationPlan| {
                let mut v: Vec<usize> = pl.tasks.iter().map(|t| t.part).collect();
                v.sort_unstable();
                v
            };
            require(parts(a) == parts(b), "per-iteration partition stream diverged")?;
        }
        Ok(())
    });
}

#[test]
fn wb_epoch_makespan_is_optimal() {
    check("wb optimal makespan", 64, |rng| {
        let p = 2 + rng.index(6);
        let counts: Vec<usize> = (0..p).map(|_| 1 + rng.index(30)).collect();
        let total: usize = counts.iter().sum();
        let mut sched = TwoStageScheduler::new(p, true);
        let plans = sched.plan_epoch(&counts);
        let makespan = hitgnn::sched::epoch_makespan_batches(&plans, p);
        // with WB each iteration runs ≤1 batch per FPGA, so the epoch
        // makespan equals the iteration count and is ≥ ceil(total/p) and
        // ≤ max(partition counts) (stage-1 forces one batch per available
        // partition per iteration)
        let lower = (total + p - 1) / p;
        let upper = total; // trivial upper bound
        require(
            makespan >= lower && makespan <= upper,
            &format!("makespan {makespan} outside [{lower}, {upper}] for {counts:?}"),
        )?;
        // never worse than baseline
        let mut base = TwoStageScheduler::new(p, false);
        let base_plans = base.plan_epoch(&counts);
        let base_makespan = hitgnn::sched::epoch_makespan_batches(&base_plans, p);
        require(
            makespan <= base_makespan,
            &format!("WB {makespan} worse than baseline {base_makespan}"),
        )
    });
}

#[test]
fn faulted_epoch_plans_train_every_batch_exactly_once() {
    // ISSUE 10 satellite: across random single-device fail points, in
    // both scheduler modes, the planned epoch still covers every
    // (part, seq) batch exactly once — the dead device's remaining work
    // drains deterministically to survivors, and the dead device executes
    // nothing from its fail iteration on.
    use hitgnn::coordinator::prep::plan_epoch_tasks_with_faults;
    use hitgnn::sampling::EpochPlan;
    check("fault exactly-once", 96, |rng| {
        let p = 2 + rng.index(6);
        let b = 4usize;
        let train_parts: Vec<Vec<u32>> =
            (0..p).map(|_| (0..rng.index(33) as u32).collect()).collect();
        let expected: Vec<usize> = train_parts.iter().map(|t| t.len().div_ceil(b)).collect();
        if expected.iter().sum::<usize>() == 0 {
            return Ok(());
        }
        let wb = rng.bool(0.5);
        let seed = rng.next_u64();
        let cost = CostModel::new((0..p).map(|_| 0.5 + rng.f64() * 4.0).collect());
        for mode in SchedMode::ALL {
            // healthy plan first: fixes the iteration range a valid
            // anchor must land in (a faulted epoch only gets longer)
            let mut plan = EpochPlan::new(&train_parts, b, &mut Rng::new(seed));
            let mut remaining: Vec<usize> = (0..p).map(|i| plan.remaining(i)).collect();
            let mut sched = TwoStageScheduler::for_mode(p, wb, mode, Some(cost.clone()));
            let healthy =
                plan_epoch_tasks_with_faults(&mut sched, &mut plan, &mut remaining, None, &[])
                    .map_err(|e| e.to_string())?;
            if healthy.is_empty() {
                continue;
            }
            let dev = rng.index(p);
            let at = rng.index(healthy.len());
            let mut plan = EpochPlan::new(&train_parts, b, &mut Rng::new(seed));
            let mut remaining: Vec<usize> = (0..p).map(|i| plan.remaining(i)).collect();
            let mut sched = TwoStageScheduler::for_mode(p, wb, mode, Some(cost.clone()));
            let faulted = plan_epoch_tasks_with_faults(
                &mut sched,
                &mut plan,
                &mut remaining,
                None,
                &[(at, dev)],
            )
            .map_err(|e| e.to_string())?;
            require(!sched.alive()[dev], "failed device must be quarantined")?;
            // exactly-once: the faulted plan covers the identical
            // (part, seq) multiset — nothing lost, nothing duplicated
            let mut pairs: Vec<(usize, usize)> =
                faulted.iter().flatten().map(|t| (t.part, t.seq)).collect();
            pairs.sort_unstable();
            let mut want: Vec<(usize, usize)> =
                (0..p).flat_map(|i| (0..expected[i]).map(move |s| (i, s))).collect();
            want.sort_unstable();
            require(
                pairs == want,
                &format!("{mode:?} dev{dev}@i{at}: coverage {pairs:?} != {want:?}"),
            )?;
            for (it, tasks) in faulted.iter().enumerate() {
                let width = if it >= at { p - 1 } else { p };
                require(tasks.len() <= width, "iteration wider than the live fleet")?;
                if it >= at {
                    require(
                        tasks.iter().all(|t| t.fpga != dev),
                        &format!("{mode:?}: dead dev{dev} executes at iteration {it} >= {at}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// partitioning
// ---------------------------------------------------------------------

#[test]
fn partitioners_cover_train_set_disjointly() {
    let d = datasets::lookup("yelp").unwrap().build(8, 99);
    check("partition totality", 12, |rng| {
        let p = 1 + rng.index(6);
        let algo = match rng.index(3) {
            0 => Algorithm::DistDgl,
            1 => Algorithm::PaGraph,
            _ => Algorithm::P3,
        };
        let pre = preprocess(algo, &d, p, rng.f64() * 0.5, rng.next_u64());
        let total: usize = pre.train_parts.iter().map(|t| t.len()).sum();
        require(total == d.train_vertices.len(), "train vertices lost/duplicated")?;
        if let Some(part) = &pre.vertex_part {
            require(part.iter().all(|&x| (x as usize) < p), "assignment in range")?;
        }
        require(pre.stores.len() == p, "one store per FPGA")
    });
}

// ---------------------------------------------------------------------
// sampler
// ---------------------------------------------------------------------

#[test]
fn sampled_batches_always_validate() {
    let d = datasets::lookup("reddit").unwrap().build(8, 55);
    check("sampler validity", 24, |rng| {
        // random depth 1..=3 with random per-layer fanouts
        let lcount = 1 + rng.index(3);
        let fanouts: Vec<usize> = (0..lcount).map(|_| 1 + rng.index(7)).collect();
        let cfg = FanoutConfig::new(1 + rng.index(64), &fanouts);
        cfg.validate().map_err(|e| e.to_string())?;
        let mode = if rng.bool(0.5) { WeightMode::GcnNorm } else { WeightMode::SageMean };
        let batch_size = cfg.batch_size;
        let mut s = Sampler::new(cfg, mode, d.graph.num_vertices(), rng.next_u64());
        let n = 1 + rng.index(batch_size.min(d.train_vertices.len()));
        let start = rng.index(d.train_vertices.len() - n + 1);
        let targets = &d.train_vertices[start..start + n];
        let mb = s.sample(&d, targets, 0, 0);
        mb.validate().map_err(|e| e.to_string())?;
        require(mb.n_targets() == n, "target count")?;
        // weights non-negative and padded rows fully zero, at every layer
        for l in 1..=lcount {
            let k = mb.dims.row_width(l);
            require(mb.w[l - 1].iter().all(|&w| w >= 0.0), "weights non-negative")?;
            for r in mb.n[l]..mb.dims.caps[l] {
                let row = &mb.w[l - 1][r * k..(r + 1) * k];
                require(row.iter().all(|&w| w == 0.0), "padding rows weightless")?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// comm conservation
// ---------------------------------------------------------------------

#[test]
fn traffic_conserves_bytes_for_all_algorithms_and_policies() {
    let d = datasets::lookup("ogbn-products").unwrap().build(8, 77);
    check("traffic conservation", 12, |rng| {
        let p = 2 + rng.index(4);
        let algo = match rng.index(3) {
            0 => Algorithm::DistDgl,
            1 => Algorithm::PaGraph,
            _ => Algorithm::P3,
        };
        let policy = match rng.index(3) {
            0 => CachePolicy::Static,
            1 => CachePolicy::Lfu,
            _ => CachePolicy::Window,
        };
        let mut pre = preprocess_with_policy(algo, &d, p, 0.3, policy, rng.next_u64());
        let cfg = FanoutConfig::new(32, &[4, 3]);
        let mut s = Sampler::new(cfg, WeightMode::GcnNorm, d.graph.num_vertices(), rng.next_u64());
        let part = rng.index(p);
        if pre.train_parts[part].len() < 32 {
            return Ok(());
        }
        let mb = s.sample(&d, &pre.train_parts[part][..32], part, 0);
        let dc = rng.bool(0.5);
        let row = d.features.bytes_per_vertex();
        let expect = (mb.n[0] * row) as u64;
        let comm = hitgnn::comm::CommConfig { direct_host_fetch: dc };
        let conserves = |label: &str, t: &hitgnn::comm::Traffic| {
            require(
                t.total_bytes() == expect,
                &format!("{label} {algo:?}/{policy:?}: {} != {expect}", t.total_bytes()),
            )?;
            require((0.0..=1.0).contains(&t.beta()), "beta in [0,1]")?;
            require((0.0..=1.0).contains(&t.hit_rate()), "hit rate in [0,1]")?;
            if dc {
                require(t.f2f_bytes == 0, "DC on → no f2f")?;
            }
            Ok(())
        };
        let snaps = pre.residency_snapshot();
        let t = hitgnn::comm::feature_traffic(
            &mb, &snaps[part], row, comm, pre.vertex_part.as_deref(), part,
        );
        conserves("cold", &t)?;
        // drive the dynamic path: observe + end_epoch, then the re-ranked
        // residency must still conserve bytes
        pre.stores[part].observe(mb.level0());
        for st in pre.stores.iter_mut() {
            st.end_epoch();
        }
        let snaps2 = pre.residency_snapshot();
        let t2 = hitgnn::comm::feature_traffic(
            &mb, &snaps2[part], row, comm, pre.vertex_part.as_deref(), part,
        );
        conserves("re-ranked", &t2)?;
        if policy.is_dynamic() {
            // a capacity-bounded dynamic cache stays capacity-bounded
            let cap = ((d.graph.num_vertices() as f64) * 0.3).round() as usize;
            require(
                snaps2[part].resident_rows() == Some(cap),
                &format!("capacity drifted: {:?} != {cap}", snaps2[part].resident_rows()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn tiered_store_partitions_miss_bytes_exactly() {
    let d = datasets::lookup("ogbn-products").unwrap().build(8, 77);
    check("tier conservation", 12, |rng| {
        let p = 2 + rng.index(4);
        let algo = match rng.index(3) {
            0 => Algorithm::DistDgl,
            1 => Algorithm::PaGraph,
            _ => Algorithm::P3,
        };
        let policy = match rng.index(3) {
            0 => CachePolicy::Static,
            1 => CachePolicy::Lfu,
            _ => CachePolicy::Window,
        };
        let pre = preprocess_with_policy(algo, &d, p, 0.3, policy, rng.next_u64());
        let cfg = FanoutConfig::new(32, &[4, 3]);
        let mut s = Sampler::new(cfg, WeightMode::GcnNorm, d.graph.num_vertices(), rng.next_u64());
        let part = rng.index(p);
        if pre.train_parts[part].len() < 32 {
            return Ok(());
        }
        let mb = s.sample(&d, &pre.train_parts[part][..32], part, 0);
        let dc = rng.bool(0.5);
        let comm = hitgnn::comm::CommConfig { direct_host_fetch: dc };
        let row = d.features.bytes_per_vertex();
        let snaps = pre.residency_snapshot();
        let mut t = hitgnn::comm::feature_traffic(
            &mb, &snaps[part], row, comm, pre.vertex_part.as_deref(), part,
        );
        // with or without fetch dedup first: dedup only relabels host
        // bytes, so the tier split must stay exact either way
        if rng.bool(0.5) {
            let mut dd = hitgnn::comm::IterDedup::new(d.graph.num_vertices());
            dd.next_iteration();
            dd.apply(mb.level0(), &snaps[part], row, comm, pre.vertex_part.as_deref(), part, &mut t);
        }
        let dram_ratio = rng.f64();
        let mut tier = TieredStore::new(
            policy,
            d.graph.num_vertices(),
            dram_ratio,
            d.features.feat_dim(),
            degree_rank(&d),
        );
        tier.charge(mb.level0(), &snaps[part], row, &mut t);
        // the tier split partitions the miss traffic exactly, so together
        // with the FPGA-local bytes it partitions the batch total
        require(
            t.dram_hit_bytes + t.disk_read_bytes == t.missed_bytes(),
            &format!(
                "{algo:?}/{policy:?} ratio {dram_ratio:.3}: dram {} + disk {} != missed {}",
                t.dram_hit_bytes,
                t.disk_read_bytes,
                t.missed_bytes()
            ),
        )?;
        require(
            t.local_bytes + t.dram_hit_bytes + t.disk_read_bytes == t.total_bytes(),
            "local + dram + disk must partition the total",
        )?;
        require((0.0..=1.0).contains(&t.dram_hit_rate()), "hit rate in [0,1]")?;
        // re-rank at the epoch barrier, then a fresh batch charge against
        // the new membership must still split exactly
        tier.observe(mb.level0());
        tier.end_epoch();
        let mut t2 = hitgnn::comm::feature_traffic(
            &mb, &snaps[part], row, comm, pre.vertex_part.as_deref(), part,
        );
        tier.charge(mb.level0(), &snaps[part], row, &mut t2);
        require(
            t2.dram_hit_bytes + t2.disk_read_bytes == t2.missed_bytes(),
            "post-barrier split must stay exact",
        )
    });
}

#[test]
fn iteration_dedup_conserves_bytes_for_all_policies() {
    let d = datasets::lookup("yelp").unwrap().build(8, 31);
    check("dedup conservation", 12, |rng| {
        let p = 2 + rng.index(3);
        let algo = match rng.index(3) {
            0 => Algorithm::DistDgl,
            1 => Algorithm::PaGraph,
            _ => Algorithm::P3,
        };
        let policy = match rng.index(3) {
            0 => CachePolicy::Static,
            1 => CachePolicy::Lfu,
            _ => CachePolicy::Window,
        };
        let pre = preprocess_with_policy(algo, &d, p, 0.2, policy, rng.next_u64());
        let cfg = FanoutConfig::new(24, &[4, 3]);
        let mut s = Sampler::new(cfg, WeightMode::GcnNorm, d.graph.num_vertices(), rng.next_u64());
        let dc = rng.bool(0.5);
        let comm = hitgnn::comm::CommConfig { direct_host_fetch: dc };
        let row = d.features.bytes_per_vertex();
        let snaps = pre.residency_snapshot();
        let mut dd = hitgnn::comm::IterDedup::new(d.graph.num_vertices());
        dd.next_iteration();
        // one iteration: a batch per FPGA, dedup applied in tag order
        let mut saved_total = 0u64;
        let mut host_total = 0u64;
        for fpga in 0..p {
            let tp = &pre.train_parts[fpga];
            if tp.len() < 24 {
                continue;
            }
            let mb = s.sample(&d, &tp[..24], fpga, 0);
            let base = hitgnn::comm::feature_traffic(
                &mb, &snaps[fpga], row, comm, pre.vertex_part.as_deref(), fpga,
            );
            let mut t = base;
            dd.apply(
                mb.level0(),
                &snaps[fpga],
                row,
                comm,
                pre.vertex_part.as_deref(),
                fpga,
                &mut t,
            );
            // dedup only reclassifies host-path bytes; everything else and
            // the per-batch total are conserved
            require(t.total_bytes() == base.total_bytes(), "total conserved")?;
            require(t.local_bytes == base.local_bytes, "local untouched")?;
            require(t.f2f_bytes == base.f2f_bytes, "f2f untouched (DC semantics)")?;
            require(
                t.host_bytes + t.dedup_saved_bytes == base.host_bytes,
                "moved bytes come from the host term only",
            )?;
            host_total += base.host_bytes;
            saved_total += t.dedup_saved_bytes;
        }
        require(saved_total <= host_total, "cannot save more than was host-fetched")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------
// performance model monotonicity
// ---------------------------------------------------------------------

#[test]
fn perf_model_monotone_in_resources_and_beta() {
    check("perf monotonicity", 64, |rng| {
        let f0 = 32.0 + rng.index(600) as f64;
        let lcount = 1 + rng.index(3);
        let mut fanouts: Vec<f64> = vec![(2 + rng.index(24)) as f64];
        for _ in 1..lcount {
            fanouts.push((2 + rng.index(10)) as f64);
        }
        let mut f = vec![f0];
        for _ in 1..lcount {
            f.push(128.0);
        }
        f.push((8 + rng.index(100)) as f64);
        let shape = BatchShape::nominal((64 + rng.index(1024)) as f64, &fanouts, &f);
        let beta = rng.f64();
        let n = 1 + rng.index(4) as u32;
        let m = 32 * (1 + rng.index(16)) as u32;
        let t1 = TimingModel::new(U250, DieConfig { n, m }, 16.0);
        let t2 = TimingModel::new(U250, DieConfig { n: n * 2, m: m * 2 }, 16.0);
        let b1 = t1.batch(&shape, beta, ModelCost::GCN).gnn_s;
        let b2 = t2.batch(&shape, beta, ModelCost::GCN).gnn_s;
        require(b2 <= b1 + 1e-12, "more PEs must not be slower")?;
        let hi = t1.batch(&shape, (beta + 0.3).min(1.0), ModelCost::GCN).gnn_s;
        require(hi <= b1 + 1e-12, "higher beta must not be slower")?;
        // the model axis prices attention: a GAT batch is never faster
        // than the matched GCN batch, and strictly slower whenever the
        // attention term is non-degenerate (it always is: a[l] > 0)
        let gat = t1.batch(&shape, beta, ModelCost::for_model("gat").unwrap()).gnn_s;
        require(gat > b1, "attention must add edge-proportional time")
    });
}

#[test]
fn epoch_estimate_scales_with_batches() {
    check("epoch scaling", 32, |rng| {
        let p = 1 + rng.index(8);
        let spec = {
            let mut s = PlatformSpec::paper_4fpga();
            s.num_fpgas = p;
            s
        };
        let model = PlatformModel::new(spec, DieConfig { n: 2, m: 512 });
        let base = 1 + rng.index(32);
        let w1 = Workload {
            shape: BatchShape::nominal(1024.0, &[25.0, 10.0], &[100.0, 128.0, 47.0]),
            beta: 0.5 + rng.f64() * 0.5,
            cost: ModelCost::GCN,
            sampling_s_per_batch: 0.0,
            batches_per_part: vec![base; p],
            workload_balancing: true,
            direct_host_fetch: true,
            extra_pcie_bytes_per_batch: 0.0,
            prefetch: false,
            disk_gbs: 0.0,
            disk_miss_frac: 0.0,
        };
        let mut w2 = w1.clone();
        w2.batches_per_part = vec![base * 2; p];
        let e1 = model.epoch(&w1);
        let e2 = model.epoch(&w2);
        require(e2.epoch_s > e1.epoch_s, "more batches take longer")?;
        // NVTPS steady-state is batch-count invariant (same per-iteration
        // composition, sync amortised identically)
        require(
            (e1.nvtps - e2.nvtps).abs() / e1.nvtps < 0.05,
            &format!("steady-state NVTPS drifted: {} vs {}", e1.nvtps, e2.nvtps),
        )
    });
}

// ---------------------------------------------------------------------
// resource model
// ---------------------------------------------------------------------

#[test]
fn resource_feasibility_is_monotone() {
    let model = ResourceModel::new(U250);
    check("resource monotone", 128, |rng| {
        let n = 1 + rng.index(12) as u32;
        let m = 1 + rng.index(800) as u32;
        let c = DieConfig { n, m };
        if model.check(c) {
            // any smaller config is also feasible
            let smaller = DieConfig { n: 1.max(n / 2), m: 1.max(m / 2) };
            require(model.check(smaller), &format!("{smaller:?} infeasible but {c:?} feasible"))
        } else {
            let larger = DieConfig { n: n + 1, m: m + 1 };
            require(!model.check(larger), &format!("{larger:?} feasible but {c:?} infeasible"))
        }
    });
}

// ---------------------------------------------------------------------
// json round-trip
// ---------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.index(4) } else { rng.index(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool(0.5)),
        2 => {
            // round-trippable numbers: i32-ish or fixed-point halves
            Json::num((rng.next_u64() as i32 as f64) / 2.0)
        }
        3 => {
            let len = rng.index(12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.index(68);
                    match c {
                        0..=25 => (b'a' + c as u8) as char,
                        26..=51 => (b'A' + (c - 26) as u8) as char,
                        52..=61 => (b'0' + (c - 52) as u8) as char,
                        62 => '"',
                        63 => '\\',
                        64 => '\n',
                        65 => '\t',
                        66 => 'é',
                        _ => ' ',
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.index(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.index(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn json_roundtrips_random_documents() {
    check("json roundtrip", 256, |rng| {
        let doc = random_json(rng, 4);
        for text in [doc.to_string(), doc.pretty()] {
            let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
            require(parsed == doc, &format!("mismatch for {text}"))?;
        }
        Ok(())
    });
}
