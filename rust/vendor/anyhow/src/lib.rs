//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the exact API surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! Differences from the real crate (deliberate, to stay tiny):
//! - `Error` stores a flattened message string; the source chain is
//!   rendered eagerly at conversion time instead of being walkable.
//! - `{:#}` and `{}` print the same (full) message; real anyhow prints
//!   only the outermost context without the alternate flag.
//!
//! Swap back to crates.io anyhow by replacing the path dependency — the
//! call sites need no changes.

use std::error::Error as StdError;
use std::fmt;

/// Flattened error value. Like `anyhow::Error`, it deliberately does NOT
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    msg: String,
}

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message (used by [`anyhow!`]).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    fn wrap(context: impl fmt::Display, inner: &Error) -> Error {
        Error { msg: format!("{context}: {}", inner.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(cause) = src {
            msg.push_str(&format!(": {cause}"));
            src = cause.source();
        }
        Error { msg }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let inner: Error = e.into();
            Error::wrap(context, &inner)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let inner: Error = e.into();
            Error::wrap(f(), &inner)
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(::std::format!($($arg)*)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return ::std::result::Result::Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad thing {}", 7)
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad thing 7");
        assert_eq!(format!("{e:#}"), "bad thing 7");
    }

    #[test]
    fn ensure_formats() {
        let r: Result<()> = (|| {
            ensure!(1 + 1 == 3, "math is broken: {}", 2);
            Ok(())
        })();
        assert!(r.unwrap_err().to_string().contains("math is broken"));
    }

    #[test]
    fn std_errors_convert_with_source_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::num::ParseIntError> =
            "x".parse::<usize>().map(|_| ());
        let e = r.context("parsing count").unwrap_err();
        assert!(e.to_string().starts_with("parsing count: "));
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let s: Option<u32> = Some(3);
        assert_eq!(s.with_context(|| "unused").unwrap(), 3);
    }
}
